//! `lspine` — CLI entrypoint of the L-SPINE reproduction.
//!
//! Subcommands:
//!   forge     — generate hermetic synthetic artifacts (no python needed)
//!   serve     — run the serving engine on synthetic request traffic, or
//!               (--listen) attach the TCP wire-protocol front end; with
//!               --models DIR every manifest model is served from a
//!               multi-tenant registry with hot swap
//!   loadgen   — open-loop load generator against a listening server
//!   admin     — registry administration over the wire protocol
//!               (load / swap / unload / list models, drain)
//!   stream    — replay a streaming (LSPS) dataset through stateful
//!               sessions with persistent membrane state
//!   eval      — evaluate a quantized artifact on the test set
//!               (native engine, PJRT, or both with cross-check)
//!   simulate  — cycle-simulate inference on the 2D NCE array
//!   report    — regenerate the paper's tables and figures
//!
//! Examples:
//!   lspine forge --out artifacts
//!   lspine eval --model mlp --bits 4 --backend both
//!   lspine simulate --model mlp --bits 2 --samples 32
//!   lspine report --all
//!   lspine serve --model mlp --bits 4 --requests 256 --concurrency 8
//!   lspine serve --backend native --listen 127.0.0.1:7317
//!   lspine serve --models artifacts --listen 127.0.0.1:7317
//!   lspine loadgen --connect 127.0.0.1:7317 --sessions 256 --drain
//!   lspine loadgen --connect 127.0.0.1:7317 --model mlp,convnet
//!   lspine admin --connect 127.0.0.1:7317 --swap mlp
//!   lspine stream --model mlp --bits 4 --steps 4 --workers 2

use std::sync::Arc;
use std::time::{Duration, Instant};

use lspine::coordinator::{
    loadgen, tcp, wire, Backend, EncoderKind, FaultPlan, LatencyHistogram,
    ModelRegistry, RegistryConfig, ReqPrecision, ServerConfig, ServingEngine,
    TcpFrontend,
};
use lspine::model::{ResetPolicy, SnnEngine};
use lspine::nce::{KernelKind, Kernels};
use lspine::reports;
use lspine::runtime::executor::{ExecutorPool, ModelKey};
use lspine::runtime::ArtifactStore;
use lspine::util::bench::Table;
use lspine::util::cli::Args;

const USAGE: &str = "\
lspine <forge|serve|loadgen|admin|stream|eval|simulate|report> [options]
  common:    --artifacts DIR (default: artifacts)  --model mlp|convnet
             --kernels auto|scalar|wide|avx2|neon (default: auto;
             env LSPINE_KERNELS sets the process default)
  forge:     --out DIR (default: artifacts)  --seed N
             --sparsity S (magnitude-prune every net to S in [0,1);
             S > 0 writes v2 block-sparse LSPW files)
  eval:      --bits 2|4|8  --scheme lspine|stbp|admm|trunc
             --backend native|pjrt|both  --samples N
             --encoder rate|delta[:G]|window:W|ttfs[:T]|pop:G (native only)
             --early-exit (native: stop each sample at its first readout
             fire; prints decision-step quantiles and the energy credit
             of the skipped timesteps)
  simulate:  --bits 2|4|8  --samples N
  serve:     --bits 2|4|8  --backend native|pjrt  --requests N  --concurrency N
             --workers N (default: available cores)
             --listen HOST:PORT (serve the TCP wire protocol instead of
             synthetic traffic; --queue N --max-sessions N size admission
             control; SIGTERM or a client Drain frame stops gracefully)
             --models DIR (serve every model in DIR's manifest from the
             multi-tenant registry and watch the manifest for membership
             changes; --model picks the default, else the first entry)
             --quota-sessions N (per-model open-session cap; default:
             --max-sessions)
             --faults SPEC (seeded fault injection, e.g.
             \"panic@6,stall@12:100ms,drop@18,reset@2\"; env LSPINE_FAULTS)
  loadgen:   --connect HOST:PORT (default 127.0.0.1:7317)
             --sessions N (default 16)  --windows N/session (default 8)
             --steps N  --bits 2|4|8
             --encoder rate|delta[:G]|window:W|ttfs[:T]|pop:G
             --early-exit (version-4 frames: the server stops integrating
             at the first readout fire; the summary gains decision_viol=
             and decision_p50/p99 keys)
             --model A[,B,...] (address sessions round-robin across
             models via version-3 frames; default: the server default)
             --rate R (windows/s/session, default 50)
             --arrival constant|burst|heavy-tail  --conns N (default auto)
             --seed N  --drain (stop the server afterwards)
             --retry-secs S (connect patience)  --timeout-secs S
             --deadline-ms MS (per-window budget; 0 = none)
             --retries N (resends on typed retriable errors, default 0)
             --backoff-ms MS (base retry backoff, default 50)
  admin:     --connect HOST:PORT (default 127.0.0.1:7317), then exactly
             one of --load MODEL | --swap MODEL | --unload MODEL |
             --list | --drain;  --timeout-secs S (socket read timeout)
  stream:    --bits 2|4|8  --steps N (timesteps/frame, default 4)
             --sessions N (concurrent streams, default 1)  --workers N
             --policy hold|reset|decay:K (window boundary, default hold)
             --encoder rate|delta[:GAIN]|window:W|ttfs[:T]|pop:G
             --input FILE|- (LSPS; default artifacts/stream.lsps)
             --stream NAME (named forged stream from the manifest, e.g.
             ecg|kws|vib; overrides --input)
             --early-exit (stop each frame-window at its first readout
             fire; prints latency-to-decision and decision-step quantiles)
  report:    --all | any of --table1 --table2 --fig4 --fig5 --energy --cpu-gpu
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn run() -> lspine::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        argv,
        &[
            "artifacts=", "model=", "bits=", "scheme=", "backend=", "samples=",
            "requests=", "concurrency=", "workers=", "kernels=", "out=", "seed=",
            "sparsity=",
            "steps=", "sessions=", "policy=", "encoder=", "input=", "listen=",
            "stream=", "early-exit",
            "queue=", "max-sessions=", "connect=", "windows=", "rate=",
            "arrival=", "conns=", "retry-secs=", "timeout-secs=", "drain",
            "faults=", "retries=", "backoff-ms=", "deadline-ms=",
            "models=", "quota-sessions=", "load=", "swap=", "unload=", "list",
            "all", "table1", "table2", "fig4", "fig5", "energy", "cpu-gpu", "help",
        ],
    )?;
    if args.has("help") || args.positional().is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional()[0].as_str();
    match cmd {
        // --kernels is parsed per-command (serve binds shards, eval and
        // simulate bind their single engine); forge/report ignore it.
        "forge" => cmd_forge(&args),
        "eval" => cmd_eval(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "admin" => cmd_admin(&args),
        "stream" => cmd_stream(&args),
        "report" => cmd_report(&args),
        other => anyhow::bail!("unknown command {other:?}"),
    }
}

fn cmd_forge(args: &Args) -> lspine::Result<()> {
    let out = args.get_or("out", "artifacts");
    let seed = match args.get("seed") {
        None => lspine::forge::DEFAULT_SEED,
        // accept both decimal and the 0x-prefixed form the tool prints
        Some(s) => match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
            Some(hex) => u64::from_str_radix(hex, 16)?,
            None => s.parse::<u64>()?,
        },
    };
    let sparsity = args.get_or("sparsity", "0").parse::<f64>()?;
    let cfg = lspine::forge::ForgeConfig { seed, sparsity, ..Default::default() };
    lspine::forge::write_artifacts(std::path::Path::new(out), &cfg)?;
    if sparsity > 0.0 {
        println!(
            "forged hermetic artifacts into {out}/ (seed {seed:#x}, {} test samples, \
             pruned to {sparsity} sparsity — v2 block-sparse LSPW)",
            cfg.n_test
        );
    } else {
        println!(
            "forged hermetic artifacts into {out}/ (seed {seed:#x}, {} test samples)",
            cfg.n_test
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> lspine::Result<()> {
    let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
    let model = args.get_or("model", "mlp");
    let bits = args.get_usize("bits", 4)? as u32;
    let scheme = args.get_or("scheme", "lspine");
    let backend = args.get_or("backend", "native");
    let kernels = parse_kernels(args)?;
    let data = store.load_test_set()?;
    let samples = args.get_usize("samples", data.n)?.min(data.n);

    println!(
        "eval: model={model} scheme={scheme} INT{bits} backend={backend} \
         kernels={} n={samples}",
        kernels.name()
    );

    if args.has("early-exit") {
        anyhow::ensure!(
            backend == "native",
            "--early-exit runs on the native engine only"
        );
        return eval_early_exit(args, &store, model, scheme, bits, kernels, &data, samples);
    }

    let native_preds = if backend != "pjrt" {
        let net = if scheme == "mixed" {
            store.load_mixed_network(model)?
        } else {
            store.load_network(model, scheme, bits)?
        };
        let mut engine = SnnEngine::with_kernels(net, kernels);
        let t0 = Instant::now();
        let preds: Vec<usize> =
            (0..samples).map(|i| engine.predict(data.sample(i))).collect();
        let dt = t0.elapsed();
        let acc = accuracy(&preds, &data, samples);
        let st = engine.last_stats();
        println!(
            "  native: acc={:.2}%  {:.3} ms/sample  (event-driven: {:.1}% of dense synops)",
            acc * 100.0,
            dt.as_secs_f64() * 1e3 / samples as f64,
            st.words_touched as f64 * engine.network().precision().fields_per_word() as f64
                * 100.0
                / st.dense_synops.max(1) as f64
        );
        Some(preds)
    } else {
        None
    };

    if backend != "native" {
        anyhow::ensure!(
            scheme == "lspine",
            "PJRT artifacts exist only for the lspine scheme"
        );
        let mut pool = ExecutorPool::new(store, model)?;
        let b = pool.best_batch(bits, 32)?;
        let exe = pool.get(ModelKey { bits, batch: b })?;
        let t0 = Instant::now();
        let mut preds = Vec::with_capacity(samples);
        for start in (0..samples).step_by(b) {
            let end = (start + b).min(samples);
            let rows: Vec<&[u8]> = (start..end).map(|i| data.sample(i)).collect();
            preds.extend(exe.predict_u8(&rows)?);
        }
        let dt = t0.elapsed();
        let acc = accuracy(&preds, &data, samples);
        println!(
            "  pjrt:   acc={:.2}%  {:.3} ms/sample (batch {b})",
            acc * 100.0,
            dt.as_secs_f64() * 1e3 / samples as f64
        );
        if let Some(native) = native_preds {
            let agree = native.iter().zip(&preds).filter(|(a, b)| a == b).count();
            println!("  cross-check: {agree}/{samples} predictions agree");
            anyhow::ensure!(agree == samples, "backends disagree!");
        }
    }
    Ok(())
}

/// `eval --early-exit`: run every sample twice — the fixed-T baseline
/// and the early-exit path (stop at the first readout fire) — and report
/// prediction agreement, decision-step quantiles, latency-to-decision,
/// and the energy credit of the skipped timesteps.
#[allow(clippy::too_many_arguments)]
fn eval_early_exit(
    args: &Args,
    store: &ArtifactStore,
    model: &str,
    scheme: &str,
    bits: u32,
    kernels: Kernels,
    data: &lspine::model::io::Dataset,
    samples: usize,
) -> lspine::Result<()> {
    use lspine::energy::EnergyModel;

    let encoder = EncoderKind::parse(args.get_or("encoder", "rate")).ok_or_else(|| {
        anyhow::anyhow!("bad --encoder (rate|delta[:GAIN]|window:W|ttfs[:T]|pop:G)")
    })?;
    let net = if scheme == "mixed" {
        store.load_mixed_network(model)?
    } else {
        store.load_network(model, scheme, bits)?
    };
    let trained_t = net.arch.timesteps();
    let neurons = net.arch.total_neurons() as u64;
    let input_dim = net.arch.input_dim();
    let raw_dim = encoder.payload_dim(input_dim).ok_or_else(|| {
        anyhow::anyhow!(
            "model input dim {input_dim} is not divisible by the population group count"
        )
    })?;
    if raw_dim != data.sample(0).len() {
        // population expands each raw pixel into its neuron group, so a
        // pop:G run feeds the first input_dim/G pixels of each sample
        println!("  note: {} feeds the first {raw_dim} pixels per sample", encoder.name());
    }

    let mut engine = SnnEngine::with_kernels(net, kernels);
    let em = EnergyModel::default();
    let (mut full_j, mut early_j) = (0.0f64, 0.0f64);
    let (mut full_s, mut early_s) = (0.0f64, 0.0f64);
    let mut decisions = Vec::with_capacity(samples);
    let (mut agree, mut hits_full, mut hits_early) = (0usize, 0usize, 0usize);
    for i in 0..samples {
        let px = &data.sample(i)[..raw_dim];
        let label = data.labels[i] as usize;

        let t_full = Instant::now();
        let mut enc = encoder.build();
        let counts = engine.infer_with_encoder(px, trained_t, &mut *enc);
        let full_pred = lspine::model::engine::argmax(counts);
        let dt = t_full.elapsed().as_secs_f64();
        full_s += dt;
        full_j += em
            .breakdown(&engine.last_stats(), bits, neurons * trained_t as u64, dt)
            .total_j();

        let t_early = Instant::now();
        let mut enc = encoder.build();
        let (pred, decision) =
            engine.infer_until_decision_with_encoder(px, trained_t, &mut *enc);
        let dt = t_early.elapsed().as_secs_f64();
        early_s += dt;
        // the energy credit of early exit: membrane updates stop at the
        // decision step (word traffic in stats already reflects it)
        early_j += em
            .breakdown(&engine.last_stats(), bits, neurons * decision as u64, dt)
            .total_j();

        decisions.push(decision);
        agree += (pred == full_pred) as usize;
        hits_full += (full_pred == label) as usize;
        hits_early += (pred == label) as usize;
    }
    decisions.sort_unstable();
    let quant =
        |q: f64| decisions[((decisions.len() - 1) as f64 * q).round() as usize];
    let mean = decisions.iter().map(|&d| d as f64).sum::<f64>() / samples as f64;
    println!(
        "  early-exit({}): acc={:.2}% vs fixed-T acc={:.2}%, agreement {agree}/{samples}",
        encoder.name(),
        hits_early as f64 * 100.0 / samples as f64,
        hits_full as f64 * 100.0 / samples as f64,
    );
    println!(
        "  decision step: mean={mean:.2} p50={} p99={} of T={trained_t}",
        quant(0.5),
        quant(0.99)
    );
    println!(
        "  latency-to-decision: {:.3} ms/sample vs {:.3} ms/sample fixed-T",
        early_s * 1e3 / samples as f64,
        full_s * 1e3 / samples as f64
    );
    println!(
        "  energy/inference: {:.3} uJ vs {:.3} uJ fixed-T ({:.1}% credit)",
        early_j * 1e6 / samples as f64,
        full_j * 1e6 / samples as f64,
        (1.0 - early_j / full_j.max(f64::MIN_POSITIVE)) * 100.0
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> lspine::Result<()> {
    use lspine::array::grid::ArrayConfig;
    use lspine::array::sim::{simulate_inference, SimOverheads};

    let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
    let model = args.get_or("model", "mlp");
    let bits = args.get_usize("bits", 2)? as u32;
    let samples = args.get_usize("samples", 16)?;
    let data = store.load_test_set()?;
    let net = store.load_network(model, "lspine", bits)?;
    let cfg = ArrayConfig::paper();
    let mut engine = SnnEngine::with_kernels(net.clone(), parse_kernels(args)?);

    println!(
        "simulate: {model} INT{bits} on {}x{} array @ {} MHz",
        cfg.rows, cfg.cols, cfg.clock_mhz
    );
    let mut cyc = 0u64;
    let mut lat = 0.0;
    let mut util = 0.0;
    let n = samples.min(data.n).max(1);
    for i in 0..n {
        engine.infer(data.sample(i));
        let r = simulate_inference(
            &net,
            &cfg,
            &SimOverheads::default(),
            engine.last_layer_stats(),
        )?;
        cyc += r.total_cycles;
        lat += r.latency_ms;
        util += r.utilization;
    }
    println!(
        "  mean over {n}: {} cycles, {:.4} ms, utilization {:.1}%",
        cyc / n as u64,
        lat / n as f64,
        util / n as f64 * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> lspine::Result<()> {
    if let Some(listen) = args.get("listen") {
        return serve_listen(args, listen);
    }
    let model = args.get_or("model", "mlp").to_string();
    let bits = args.get_usize("bits", 4)?;
    let backend = match args.get_or("backend", "pjrt") {
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let n_requests = args.get_usize("requests", 256)?;
    let concurrency = args.get_usize("concurrency", 8)?.max(1);
    let workers = args
        .get_usize("workers", lspine::coordinator::default_workers())?
        .max(1);
    let kernel_kind = parse_kernel_kind(args)?;
    let precision = ReqPrecision::parse(&bits.to_string())
        .ok_or_else(|| anyhow::anyhow!("bad bits"))?;

    let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
    let data = store.load_test_set()?;
    let engine = ServingEngine::start(ServerConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        model: model.clone(),
        backend,
        workers,
        kernels: kernel_kind,
        ..Default::default()
    })?;

    println!(
        "serve: {model} {} backend={backend:?} requests={n_requests} \
         concurrency={concurrency} workers={workers} kernels={}",
        precision.name(),
        Kernels::for_kind(kernel_kind)?.name()
    );
    let t0 = Instant::now();
    let mut hits = 0usize;
    let mut inflight = Vec::new();
    for i in 0..n_requests {
        let idx = i % data.n;
        inflight.push((idx, engine.submit(data.sample(idx), precision)?));
        if inflight.len() >= concurrency {
            let (idx, rx) = inflight.remove(0);
            let resp = rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?;
            hits += (!resp.rejected && resp.prediction == data.labels[idx] as usize)
                as usize;
        }
    }
    for (idx, rx) in inflight {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("engine died"))?;
        hits +=
            (!resp.rejected && resp.prediction == data.labels[idx] as usize) as usize;
    }
    let dt = t0.elapsed();
    println!(
        "  {} req in {:.2} s = {:.1} req/s, accuracy {:.2}%",
        n_requests,
        dt.as_secs_f64(),
        n_requests as f64 / dt.as_secs_f64(),
        hits as f64 * 100.0 / n_requests as f64
    );
    println!("  {}", engine.metrics().summary());
    engine.shutdown()
}

/// `serve --listen HOST:PORT`: attach the TCP wire-protocol front end
/// to a model registry and run until a SIGTERM/SIGINT or a client's
/// `Drain` frame asks for a graceful drain (stop accepting, flush every
/// in-flight reply, join, print the final per-model metrics).
///
/// Without `--models` the registry serves the single `--model`; with
/// `--models DIR` every model in `DIR/manifest.json` is served and a
/// watcher thread mirrors later manifest membership changes (admin
/// frames can load/swap/unload models either way).
fn serve_listen(args: &Args, listen: &str) -> lspine::Result<()> {
    // streaming sessions need the native backend, so that is the
    // network-mode default (PJRT still serves one-shot-only deployments)
    let backend = match args.get_or("backend", "native") {
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let workers = args
        .get_usize("workers", lspine::coordinator::default_workers())?
        .max(1);
    let kernel_kind = parse_kernel_kind(args)?;
    let queue_capacity = args.get_usize("queue", 1024)?.max(1);
    let max_sessions = args.get_usize("max-sessions", 1024)?.max(1);
    let quota_sessions = args.get_usize("quota-sessions", 0)?;
    // --faults wins over the LSPINE_FAULTS env var; both default empty
    // (and an empty plan costs nothing on the serving path)
    let faults = Arc::new(match args.get("faults") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::from_env()?,
    });

    // --models DIR doubles as the artifacts directory; the default model
    // is --model if given, else the manifest's first entry
    let models_dir = args.get("models").map(str::to_string);
    let artifacts = match &models_dir {
        Some(d) => d.clone(),
        None => args.get_or("artifacts", "artifacts").to_string(),
    };
    let model = match (args.get("model"), &models_dir) {
        (Some(m), _) => m.to_string(),
        (None, Some(dir)) => ArtifactStore::open(dir)?
            .manifest()
            .models
            .keys()
            .next()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("manifest in {dir} lists no models"))?,
        (None, None) => "mlp".to_string(),
    };

    let registry = Arc::new(ModelRegistry::start(RegistryConfig {
        server: ServerConfig {
            artifacts_dir: artifacts,
            model,
            backend,
            workers,
            kernels: kernel_kind,
            queue_capacity,
            max_sessions,
            faults: Arc::clone(&faults),
            ..Default::default()
        },
        quota_sessions,
    })?);
    let mut watcher = None;
    if let Some(dir) = &models_dir {
        // load is idempotent, so the already-live default just no-ops
        for name in ArtifactStore::open(dir)?.manifest().models.keys() {
            registry
                .load(name)
                .map_err(|e| anyhow::anyhow!("loading model \"{name}\": {e}"))?;
        }
        watcher = Some(spawn_manifest_watcher(Arc::clone(&registry), dir.clone()));
    }

    let frontend = TcpFrontend::bind_registry(Arc::clone(&registry), listen)?;
    tcp::install_term_handler();
    let names: Vec<String> = registry.list().into_iter().map(|s| s.name).collect();
    println!(
        "serve: models=[{}] default={} backend={backend:?} workers={workers} \
         queue={queue_capacity} max_sessions={max_sessions} listening on {}",
        names.join(","),
        registry.default_model(),
        frontend.local_addr()
    );
    if !faults.is_empty() {
        println!("  {}", faults.summary());
    }
    while !tcp::term_requested() && !frontend.draining() {
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("draining: flushing in-flight replies");
    frontend.shutdown()?;
    if let Some((stop, handle)) = watcher {
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let _ = handle.join();
    }
    let mut table =
        Table::new(&["model", "version", "requests", "windows", "rejected", "p99_us"]);
    for (name, version, m) in registry.metrics_by_model() {
        table.row(&[
            name,
            version.to_string(),
            m.requests.to_string(),
            m.stream_windows.to_string(),
            m.rejected.to_string(),
            m.latency.quantile_us(0.99).to_string(),
        ]);
    }
    print!("{}", table.to_string());
    println!("  {}", registry.metrics().summary());
    let registry = Arc::try_unwrap(registry)
        .map_err(|_| anyhow::anyhow!("front end still holds the registry"))?;
    registry.shutdown()
}

/// Poll `dir/manifest.json` (every 500 ms) and mirror membership changes
/// into the registry: newly listed models load, delisted models unload.
/// A refused unload (open sessions) is retried when the manifest next
/// changes — or the operator unloads it over the admin surface.
fn spawn_manifest_watcher(
    registry: Arc<ModelRegistry>,
    dir: String,
) -> (Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>) {
    use std::sync::atomic::{AtomicBool, Ordering};
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("manifest-watch".into())
        .spawn(move || {
            let manifest = std::path::Path::new(&dir).join("manifest.json");
            let mtime =
                |p: &std::path::Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
            let mut last = mtime(&manifest);
            while !flag.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(500));
                let now = mtime(&manifest);
                if now == last {
                    continue;
                }
                last = now;
                let Ok(store) = ArtifactStore::open(&dir) else { continue };
                let wanted: std::collections::BTreeSet<String> =
                    store.manifest().models.keys().cloned().collect();
                drop(store);
                for status in registry.list() {
                    if !wanted.contains(&status.name) {
                        match registry.unload(&status.name) {
                            Ok(()) => println!("manifest: unloaded model={}", status.name),
                            Err(e) => eprintln!("manifest: unload {}: {e}", status.name),
                        }
                    }
                }
                for name in &wanted {
                    if registry.resolve(Some(name)).is_err() {
                        match registry.load(name) {
                            Ok(v) => println!(
                                "manifest: loaded model={name} version={}",
                                v.version()
                            ),
                            Err(e) => eprintln!("manifest: load {name}: {e}"),
                        }
                    }
                }
            }
        })
        .expect("spawn manifest watcher");
    (stop, handle)
}

/// `admin`: registry administration over the version-3 wire protocol —
/// load/swap/unload/list models on a listening server, or ask it to
/// drain. Prints one stable greppable line per action (the swap-smoke
/// CI target greps `swapped model=... version=...`).
fn cmd_admin(args: &Args) -> lspine::Result<()> {
    use lspine::coordinator::wire::{Request, Response};
    use std::io::{Read, Write};

    let addr = args.get_or("connect", "127.0.0.1:7317");
    let req = if let Some(m) = args.get("load") {
        Request::AdminLoad { model: m.to_string() }
    } else if let Some(m) = args.get("swap") {
        Request::AdminSwap { model: m.to_string() }
    } else if let Some(m) = args.get("unload") {
        Request::AdminUnload { model: m.to_string() }
    } else if args.has("list") {
        Request::AdminList
    } else if args.has("drain") {
        Request::Drain
    } else {
        anyhow::bail!("pick one of --load M | --swap M | --unload M | --list | --drain");
    };

    let mut conn = std::net::TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(
        args.get_usize("timeout-secs", 10)? as u64,
    )))?;
    conn.write_all(&wire::encode_request_v3(1, &req, 0))?;
    let mut hdr = [0u8; wire::HEADER_LEN];
    conn.read_exact(&mut hdr)?;
    let h = wire::decode_header(&hdr)
        .map_err(|e| anyhow::anyhow!("bad response header: {}", e.message))?;
    let mut body = vec![0u8; h.body_len as usize];
    conn.read_exact(&mut body)?;
    let resp = wire::decode_response(h.kind, &body)
        .map_err(|e| anyhow::anyhow!("bad response body: {}", e.message))?;

    match resp {
        Response::AdminLoaded { model, version } => {
            println!("loaded model={model} version={version}");
        }
        Response::AdminSwapped { model, version } => {
            println!("swapped model={model} version={version}");
        }
        Response::AdminUnloaded { model } => println!("unloaded model={model}"),
        Response::AdminList(models) => {
            for m in models {
                println!(
                    "model={} version={} sessions={}{}",
                    m.name,
                    m.version,
                    m.sessions,
                    if m.default { " default" } else { "" }
                );
            }
        }
        Response::DrainAck => println!("drain acknowledged"),
        Response::Error { code, message } => {
            anyhow::bail!("server refused ({code:?}): {message}");
        }
        other => anyhow::bail!("unexpected response: {other:?}"),
    }
    Ok(())
}

/// Open-loop load generation against a `serve --listen` server.
fn cmd_loadgen(args: &Args) -> lspine::Result<()> {
    let bits = args.get_usize("bits", 4)?;
    let cfg = loadgen::LoadgenConfig {
        addr: args.get_or("connect", "127.0.0.1:7317").into(),
        sessions: args.get_usize("sessions", 16)?.max(1),
        windows: args.get_usize("windows", 8)?.max(1),
        steps: args.get_usize("steps", 4)?.max(1) as u32,
        precision: ReqPrecision::parse(&bits.to_string())
            .ok_or_else(|| anyhow::anyhow!("bad bits"))?,
        encoder: EncoderKind::parse(args.get_or("encoder", "rate")).ok_or_else(|| {
            anyhow::anyhow!("bad --encoder (rate|delta[:GAIN]|window:W|ttfs[:T]|pop:G)")
        })?,
        rate: args.get_or("rate", "50").parse::<f64>()?,
        arrival: loadgen::Arrival::parse(args.get_or("arrival", "constant"))
            .ok_or_else(|| anyhow::anyhow!("bad --arrival (constant|burst|heavy-tail)"))?,
        conns: args.get_usize("conns", 0)?,
        seed: args.get_usize("seed", 1)? as u64,
        drain: args.has("drain"),
        connect_retry: Duration::from_secs(args.get_usize("retry-secs", 5)? as u64),
        timeout: Duration::from_secs(args.get_usize("timeout-secs", 10)? as u64),
        retries: args.get_usize("retries", 0)? as u32,
        backoff: Duration::from_millis(args.get_usize("backoff-ms", 50)?.max(1) as u64),
        deadline_ms: args.get_usize("deadline-ms", 0)? as u32,
        // --model a,b,c spreads sessions round-robin across models
        // (version-3 opens); empty = version-1 opens on the default model
        models: args
            .get("model")
            .map(|s| {
                s.split(',')
                    .map(|m| m.trim().to_string())
                    .filter(|m| !m.is_empty())
                    .collect()
            })
            .unwrap_or_default(),
        early_exit: args.has("early-exit"),
    };
    println!(
        "loadgen: connect={} sessions={} windows={} steps={} {} rate={}/s \
         arrival={} encoder={}{} models=[{}]",
        cfg.addr,
        cfg.sessions,
        cfg.windows,
        cfg.steps,
        cfg.precision.name(),
        cfg.rate,
        cfg.arrival.name(),
        cfg.encoder.name(),
        if cfg.early_exit { " early-exit" } else { "" },
        if cfg.models.is_empty() { "default".to_string() } else { cfg.models.join(",") }
    );
    let report = loadgen::run(&cfg)?;
    println!("  {}", report.summary());
    if let Some(m) = &report.server {
        println!(
            "  server: requests={} stream_windows={} rejected={} panics={} \
             restarts={} rehomed={} deadline_exceeded={} p50_us={} \
             p99_us={} p999_us={} max_us={}",
            m.requests, m.stream_windows, m.rejected, m.panics, m.restarts,
            m.rehomed, m.deadline_exceeded, m.p50_us, m.p99_us, m.p999_us, m.max_us
        );
    }
    lspine::util::bench::emit_json_scalar(
        "loadgen",
        &format!("sessions={}", cfg.sessions),
        &[
            ("req_per_s", report.req_per_s()),
            ("p50_us", report.latency.quantile_us(0.5) as f64),
            ("p99_us", report.latency.quantile_us(0.99) as f64),
            ("p999_us", report.latency.quantile_us(0.999) as f64),
            ("ttfp_p50_us", report.ttfp.quantile_us(0.5) as f64),
            ("rejected", report.rejected as f64),
            ("protocol_errors", report.protocol_errors as f64),
            ("decision_viol", report.decision_viol as f64),
            ("decision_p50", report.decision_quantile(0.5) as f64),
            ("decision_p99", report.decision_quantile(0.99) as f64),
        ],
    );
    Ok(())
}

/// Replay a streaming dataset through stateful serving sessions: one
/// frame per request, membrane state persistent across frames, per
/// labeled window an aggregated prediction.
fn cmd_stream(args: &Args) -> lspine::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let model = args.get_or("model", "mlp").to_string();
    let bits = args.get_usize("bits", 4)?;
    let steps = args.get_usize("steps", 4)?.max(1) as u32;
    let sessions = args.get_usize("sessions", 1)?.max(1);
    let workers = args
        .get_usize("workers", lspine::coordinator::default_workers())?
        .max(1);
    let policy = ResetPolicy::parse(args.get_or("policy", "hold"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy (hold|reset|decay:K)"))?;
    let encoder = EncoderKind::parse(args.get_or("encoder", "rate")).ok_or_else(|| {
        anyhow::anyhow!("bad --encoder (rate|delta[:GAIN]|window:W|ttfs[:T]|pop:G)")
    })?;
    let precision = ReqPrecision::parse(&bits.to_string())
        .ok_or_else(|| anyhow::anyhow!("bad bits"))?;
    let kernel_kind = parse_kernel_kind(args)?;
    let early_exit = args.has("early-exit");

    // stream source: a named forged stream from the manifest, an explicit
    // LSPS file, `-` for LSPS bytes on stdin, or the forged artifacts'
    // default stream.lsps
    let data = match (args.get("stream"), args.get("input")) {
        (Some(name), _) => ArtifactStore::open(&artifacts)?.load_stream_named(name)?,
        (None, Some("-")) => {
            use std::io::Read;
            let mut blob = Vec::new();
            std::io::stdin().read_to_end(&mut blob)?;
            lspine::model::parse_stream(&blob)?
        }
        (None, Some(path)) => lspine::model::load_stream(path)?,
        (None, None) => ArtifactStore::open(&artifacts)?.load_stream_set()?,
    };

    let engine = ServingEngine::start(ServerConfig {
        artifacts_dir: artifacts,
        model: model.clone(),
        backend: Backend::Native,
        workers,
        kernels: kernel_kind,
        stream_policy: policy,
        ..Default::default()
    })?;
    println!(
        "stream: {model} {} frames={} window={} sessions={sessions} \
         workers={workers} steps={steps} policy={} encoder={}{} kernels={}",
        precision.name(),
        data.frames,
        data.window,
        policy.name(),
        encoder.name(),
        if early_exit { " early-exit" } else { "" },
        Kernels::for_kind(kernel_kind)?.name()
    );

    let ids: Vec<u64> = (0..sessions).map(|_| engine.open_stream()).collect();
    let mut win_counts = vec![vec![0i64; data.classes]; sessions];
    let mut lat = LatencyHistogram::new();
    let mut decisions: Vec<u32> = Vec::new();
    let mut nonzero_windows = 0usize;
    let mut agree = 0usize;
    let mut total_windows = 0usize;
    let t0 = Instant::now();
    for f in 0..data.frames {
        // one frame-window per session in flight: sessions parallelize
        // across workers (affinity), frames within a session stay ordered
        let rxs: Vec<_> = ids
            .iter()
            .map(|&sid| {
                engine.stream_window_full(
                    sid, data.frame(f), steps, precision, encoder, None, early_exit,
                )
            })
            .collect::<lspine::Result<_>>()?;
        let boundary = (f + 1) % data.window == 0;
        for (s, rx) in rxs.into_iter().enumerate() {
            // a rejected window (typed backpressure) or a closed reply
            // (dead worker) means the replay has a gap and cannot
            // continue faithfully
            let resp = rx.recv().map_err(|_| {
                anyhow::anyhow!("stream window dropped at frame {f} (worker failure)")
            })?;
            anyhow::ensure!(
                !resp.rejected,
                "stream window rejected at frame {f} (queue over capacity; \
                 lower --sessions or raise capacity)"
            );
            lat.record(Duration::from_micros(resp.latency_us));
            if let Some(d) = resp.decision_step {
                decisions.push(d);
            }
            for (w, &c) in win_counts[s].iter_mut().zip(&resp.counts) {
                *w += c as i64;
            }
            if boundary {
                let wdx = f / data.window;
                let label = data.labels[wdx] as usize;
                let counts = &mut win_counts[s];
                let pred = lspine::model::engine::argmax(counts);
                let spikes: i64 = counts.iter().sum();
                total_windows += 1;
                nonzero_windows += (spikes > 0) as usize;
                agree += (pred == label) as usize;
                if s == 0 && wdx < 5 {
                    println!(
                        "  window {wdx:>3}: pred={pred} label={label} spikes={spikes}"
                    );
                }
                counts.fill(0);
            }
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    for &sid in &ids {
        engine.close_stream(sid)?;
    }
    println!(
        "  windows={total_windows} nonzero_windows={nonzero_windows} \
         label_agreement={:.1}%",
        agree as f64 * 100.0 / total_windows.max(1) as f64
    );
    println!(
        "  {:.0} frame-windows/s  inter-window latency p50<={}us p99<={}us",
        (data.frames * sessions) as f64 / dt,
        lat.quantile_us(0.5),
        lat.quantile_us(0.99)
    );
    if !decisions.is_empty() {
        // latency-to-decision: the recorded per-window latency already
        // stops at the readout fire, so the quantiles above are it; the
        // decision-step quantiles say how many timesteps were bought
        decisions.sort_unstable();
        let quant =
            |q: f64| decisions[((decisions.len() - 1) as f64 * q).round() as usize];
        let mean =
            decisions.iter().map(|&d| d as f64).sum::<f64>() / decisions.len() as f64;
        println!(
            "  decision step: mean={mean:.2} p50={} p99={} of steps={steps}",
            quant(0.5),
            quant(0.99)
        );
    }
    println!("  {}", engine.metrics().summary());
    engine.shutdown()
}

fn cmd_report(args: &Args) -> lspine::Result<()> {
    let all = args.has("all");
    let mut printed = false;
    if all || args.has("table1") {
        println!("{}", reports::table1_report());
        printed = true;
    }
    if all || args.has("table2") {
        let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
        let model = args.get_or("model", "mlp");
        let net = store.load_network(model, "lspine", 2)?;
        let data = store.load_test_set()?;
        let m = reports::table2::measure_proposed(&net, &data, 16)?;
        println!("{}", reports::table2_report(&m, model));
        printed = true;
    }
    if all || args.has("fig4") {
        let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
        for model in store.manifest().models.keys() {
            println!("{}", reports::fig4_report(store.manifest(), model)?);
        }
        printed = true;
    }
    if all || args.has("fig5") {
        let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
        println!("{}", reports::fig5_report(store.manifest())?);
        printed = true;
    }
    if all || args.has("energy") {
        println!("{}", reports::energy_report(0.54));
        printed = true;
    }
    if all || args.has("cpu-gpu") {
        println!("{}", reports::cpu_gpu_report());
        printed = true;
    }
    if !printed {
        anyhow::bail!("pick --all or at least one report flag");
    }
    Ok(())
}

/// `--kernels` as a requested kind (serve: resolved by each shard).
fn parse_kernel_kind(args: &Args) -> lspine::Result<KernelKind> {
    let s = args.get_or("kernels", "auto");
    KernelKind::parse(s)
        .ok_or_else(|| anyhow::anyhow!("bad --kernels {s:?} (auto|scalar|wide|avx2|neon)"))
}

/// `--kernels` resolved to a runnable backend (eval/simulate).
fn parse_kernels(args: &Args) -> lspine::Result<Kernels> {
    Kernels::for_kind(parse_kernel_kind(args)?)
}

fn accuracy(preds: &[usize], data: &lspine::model::io::Dataset, n: usize) -> f64 {
    preds
        .iter()
        .zip(&data.labels[..n])
        .filter(|(&p, &l)| p == l as usize)
        .count() as f64
        / n as f64
}
