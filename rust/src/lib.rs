//! # L-SPINE — Low-Precision SIMD Spiking Neural Compute Engine
//!
//! Full-stack reproduction of *L-SPINE: A Low-Precision SIMD Spiking Neural
//! Compute Engine for Resource-efficient Edge Inference* (CS.AR 2026).
//!
//! The crate is the **Layer-3 coordinator + simulated accelerator** of the
//! three-layer architecture described in `DESIGN.md`:
//!
//! - [`nce`] — bit-accurate model of the paper's multi-precision SIMD
//!   neuron compute engine (Fig. 2): packed-word SIMD lanes, shift-add
//!   multiplier-less LIF dynamics, the full-adder tree structure.
//! - [`array`] — cycle-level simulator of the 2D NCE array, scratchpads,
//!   ring FIFO, spike buffer, leak FSM and spike counter (Fig. 1).
//! - [`riscv`] — the pico-rv32-class RV32I controller that orchestrates
//!   layer execution over an MMIO bus.
//! - [`encode`] — spike encoders (deterministic rate, Poisson, TTFS).
//! - [`quant`] — the packing/quantization contract shared with the python
//!   author path (`python/compile/`).
//! - [`model`] — artifact loaders (LSPW weights / LSPD datasets / JSON
//!   manifest) and the bit-accurate integer inference engine.
//! - [`forge`] — hermetic, seed-deterministic artifact generator (the
//!   write side of the LSPW/LSPD/manifest contract): synthetic weights,
//!   datasets and manifests so tests and benches run without the python
//!   author path. See DESIGN.md §Testing.
//! - [`neurons`] + [`cordic`] — baseline neuron implementations used by
//!   the paper's Table I comparison (CORDIC Izhikevich, Hodgkin–Huxley
//!   variants, AdEx, ...).
//! - [`fpga`] — structural LUT/FF/delay/power estimator (Virtex-7
//!   primitive costs) that regenerates Tables I and II.
//! - [`perf`] — CPU/GPU roofline models for the §III-D comparisons.
//! - [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas graphs
//!   (HLO text artifacts; python never runs at inference time).
//! - [`coordinator`] — the async edge-serving engine: request router,
//!   dynamic batcher, sharded execution workers, stateful stream
//!   sessions (persistent membranes, session-affine routing) and metrics.
//! - [`reports`] — regenerators for every table and figure in the paper.
//!
//! # Quick start
//!
//! Everything is hermetic: [`forge`] generates deterministic artifacts
//! in-process, so no python author path is needed to run inference.
//!
//! ```
//! use lspine::forge;
//! use lspine::model::SnnEngine;
//! use lspine::quant::QuantScheme;
//! use lspine::nce::Precision;
//!
//! let arch = forge::golden_mlp_arch();
//! let net = forge::quantized_network(&arch, 7, "doc", QuantScheme::LSpine, Precision::Int4);
//! let mut engine = SnnEngine::new(net);
//! let pixels = forge::pixels(7, 1, arch.input_dim());
//! let class = engine.predict(&pixels);
//! assert!(class < arch.classes());
//! ```
//!
//! The documented public surface is enforced: `#![warn(missing_docs)]`
//! here plus `cargo doc --no-deps` with `RUSTDOCFLAGS="-D warnings"` in
//! CI (broken intra-doc links fail the build).

#![warn(missing_docs)]

pub mod array;
pub mod util;
pub mod coordinator;
pub mod cordic;
pub mod encode;
pub mod energy;
pub mod forge;
pub mod fpga;
pub mod model;
pub mod nce;
pub mod neurons;
pub mod perf;
pub mod quant;
pub mod reports;
pub mod riscv;
pub mod runtime;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
