//! Bit-accurate integer SNN inference engine over the NCE datapath.
//!
//! Executes a [`QuantNetwork`] sample-by-sample with *exactly* the integer
//! semantics of the AOT'd pallas graph (`python/compile/model.py`):
//! deterministic rate encoding, per-layer LIF steps, im2col convolution
//! (feature order `c*9 + ky*3 + kx`, SAME zero padding — pinned to
//! `lax.conv_general_dilated_patches`), 2x2 max-pool (OR on binary
//! spikes), and spike-count outputs. `rust/tests/integration.rs` asserts
//! count-for-count equality against the PJRT execution of the HLO.
//!
//! All buffers are preallocated in [`SnnEngine::new`]; `infer` performs no
//! heap allocation (the serving hot path budget — see EXPERIMENTS.md §Perf).
//!
//! Spikes are stored bit-packed (§Perf P5): every spike buffer is a
//! [`SpikePlane`] (one bit per neuron), so the event-driven scan skips 64
//! silent inputs per instruction, the 2x2 max-pool is a word-wide OR and
//! im2col is a bit gather over the §Perf P4 tables. The u8 `im2col` /
//! `maxpool2` helpers below remain as the byte-domain references the
//! proptests pin the plane kernels against.

use crate::encode::RateEncoder;
use crate::nce::lif::LifParams;
use crate::nce::spikeplane::SpikePlane;
use crate::nce::{KernelBackend, Kernels, NeuronComputeEngine, SparseRowIndex};

use super::network::{ArchDesc, QuantNetwork};

/// What happens to the membrane state at a stream-window boundary.
///
/// One-shot classification resets membranes per sample; a *stream* keeps
/// them alive so temporal context crosses window boundaries. The policy
/// is applied once per boundary (before the new window's first timestep):
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResetPolicy {
    /// Keep membranes exactly as the previous window left them — the
    /// bit-exactness contract: a held stream session equals the same
    /// windows run back-to-back on one persistent engine, and the LIF
    /// dynamics compose exactly across the split (pinned by
    /// `tests/streaming.rs` and the engine's compose test; note each
    /// window encodes its frame from `t = 0` — the rate code's phase is
    /// window-local by design).
    Hold,
    /// Zero all membranes — every window is an independent inference
    /// (the one-shot semantics, expressed as a stream).
    Reset,
    /// Apply one extra multiplier-less leak step, `v -= v >> shift`, to
    /// every membrane — context decays across gaps without a hard reset
    /// (the shift plays the role of the inter-window time constant).
    Decay(u32),
}

impl ResetPolicy {
    /// Parse the CLI surface: `hold`, `reset` or `decay:K` with
    /// `1 <= K < 31` (`decay:0` is rejected: `v -= v >> 0` zeroes every
    /// membrane, i.e. it silently behaves as `reset` — ask for `reset`).
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "hold" => Some(ResetPolicy::Hold),
            "reset" => Some(ResetPolicy::Reset),
            _ => {
                let shift = s.strip_prefix("decay:")?.parse::<u32>().ok()?;
                (1..31).contains(&shift).then_some(ResetPolicy::Decay(shift))
            }
        }
    }

    /// Stable display name (`hold` / `reset` / `decay:K`).
    pub fn name(self) -> String {
        match self {
            ResetPolicy::Hold => "hold".into(),
            ResetPolicy::Reset => "reset".into(),
            ResetPolicy::Decay(k) => format!("decay:{k}"),
        }
    }
}

/// Snapshot of all per-layer membrane potentials — the state a
/// [`StreamSession`](crate::coordinator::session::StreamSession) keeps
/// alive between windows.
///
/// Obtained from [`SnnEngine::fresh_state`] and exchanged with the engine
/// through [`SnnEngine::swap_state`], so one engine can serve many
/// sessions without cloning membranes on the hot path.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MembraneState {
    layers: Vec<Vec<i32>>,
}

impl MembraneState {
    /// Per-layer membrane slices (read-only; tests and the decay policy
    /// inspect these).
    pub fn layers(&self) -> &[Vec<i32>] {
        &self.layers
    }

    /// Total neurons captured across layers.
    pub fn total_neurons(&self) -> usize {
        self.layers.iter().map(|l| l.len()).sum()
    }
}

/// Execution statistics of one inference (inputs to the energy model and
/// cross-checks for the cycle simulator).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InferStats {
    /// Input rows that carried a spike, summed over layers/steps/positions.
    pub active_rows: u64,
    /// Packed weight words streamed from the scratchpads.
    pub words_touched: u64,
    /// Total output spikes across all layers and steps.
    pub spikes_emitted: u64,
    /// Dense upper bound of synaptic ops (for sparsity accounting).
    pub dense_synops: u64,
}

/// Per-layer activity aggregated over all timesteps of one inference —
/// the workload description the cycle simulator schedules.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerStats {
    /// Spatial positions the layer's dense step runs at (per timestep).
    pub positions: u64,
    /// Active (spiking) input rows, summed over steps and positions.
    pub active_rows: u64,
    /// Packed words streamed, summed over steps and positions.
    pub words_touched: u64,
    /// Output spikes, summed over steps and positions.
    pub spikes_emitted: u64,
    /// Output neurons per position.
    pub n_out: u64,
    /// Packed words per weight row.
    pub n_words: u64,
}

/// Reusable single-sample inference engine (one engine per worker thread).
///
/// ```
/// use lspine::forge;
/// use lspine::model::SnnEngine;
/// use lspine::nce::Precision;
///
/// let arch = forge::golden_mlp_arch();
/// let net = forge::raw_network(&arch, 1, Precision::Int2, 4);
/// let mut engine = SnnEngine::new(net);
///
/// // one-shot classification (membranes reset per sample)
/// let pixels = forge::pixels(1, 1, arch.input_dim());
/// assert!(engine.predict(&pixels) < arch.classes());
///
/// // streaming: ragged windows over persistent membranes
/// engine.reset();
/// let w0 = engine.infer_window(&pixels, 3).to_vec();
/// let w1 = engine.infer_window(&pixels, 2).to_vec();
/// assert_eq!((w0.len(), w1.len()), (arch.classes(), arch.classes()));
/// ```
#[derive(Debug, Clone)]
pub struct SnnEngine {
    net: QuantNetwork,
    /// Per-layer i8 weight shadow, unpacked once at load (§Perf P3):
    /// the functional hot path reads these; packed words remain the
    /// storage/accounting model. INT2/4/8 all fit i8 exactly.
    unpacked: Vec<Vec<i8>>,
    /// Per-layer zero-block skip indexes (§Sparse), built at load ONLY
    /// when the artifact is marked sparse (`net.sparse_weights`) — never
    /// inferred from zero-valued words, so dense nets keep the pinned
    /// `words_touched == active_rows * n_words` accounting. Empty for
    /// dense nets; when present, every LIF step routes through the
    /// sparse walk.
    sparse_idx: Vec<SparseRowIndex>,
    /// Per-layer membrane state, flattened over spatial positions.
    membranes: Vec<Vec<i32>>,
    /// Per-layer output spike planes (bit-packed; conv layers use
    /// word-aligned per-position blocks, the fc/MLP layers are flat).
    spike_bufs: Vec<SpikePlane>,
    /// Input spike plane (encoder output), flat.
    input_spikes: SpikePlane,
    /// Per-conv-layer im2col patch planes (grid: positions x 9*ch bits).
    patch_bufs: Vec<SpikePlane>,
    /// Post-pool planes (flat — the layout the next gather / fc reads).
    pool_bufs: Vec<SpikePlane>,
    /// Precomputed im2col gather tables for the two conv layers (§Perf
    /// P4); entries are bit indices into the (flat) source plane.
    im2col_tables: Vec<Vec<u32>>,
    nce: NeuronComputeEngine,
    counts: Vec<u32>,
    stats: InferStats,
    layer_stats: Vec<LayerStats>,
}

impl SnnEngine {
    /// Engine on the process-default kernel backend (`LSPINE_KERNELS`
    /// env or auto detection).
    pub fn new(net: QuantNetwork) -> Self {
        Self::with_kernels(net, Kernels::from_env())
    }

    /// Engine bound to an explicit kernel backend (what the serving
    /// shards use — `ServerConfig::kernels` resolves once at startup).
    pub fn with_kernels(net: QuantNetwork, kernels: Kernels) -> Self {
        let (membranes, spike_bufs, patch_bufs, pool_bufs) = match &net.arch {
            ArchDesc::Mlp { sizes, .. } => {
                let m: Vec<Vec<i32>> =
                    sizes[1..].iter().map(|&n| vec![0i32; n]).collect();
                let s: Vec<SpikePlane> =
                    sizes[1..].iter().map(|&n| SpikePlane::flat(n)).collect();
                (m, s, Vec::new(), Vec::new())
            }
            ArchDesc::Convnet { side, channels, classes, .. } => {
                let (s1, s2, s4) = (*side, side / 2, side / 4);
                let (c0, c1, c2) = (channels[0], channels[1], channels[2]);
                let m = vec![
                    vec![0i32; s1 * s1 * c1],
                    vec![0i32; s2 * s2 * c2],
                    vec![0i32; *classes],
                ];
                // conv layers write word-aligned per-position blocks; the
                // fc output is flat (its logical order is the class index)
                let s = vec![
                    SpikePlane::grid(s1 * s1, c1),
                    SpikePlane::grid(s2 * s2, c2),
                    SpikePlane::flat(*classes),
                ];
                let patch = vec![
                    SpikePlane::grid(s1 * s1, 9 * c0),
                    SpikePlane::grid(s2 * s2, 9 * c1),
                ];
                // pool outputs are flat: the layout the following im2col
                // gather tables and the fc event scan index directly
                let pool = vec![
                    SpikePlane::flat(s2 * s2 * c1),
                    SpikePlane::flat(s4 * s4 * c2),
                ];
                (m, s, patch, pool)
            }
        };
        let classes = net.arch.classes();
        let input_dim = net.arch.input_dim();
        // unpack each layer once; sign-extension semantics identical to
        // the packed path (pinned by the nce tests)
        let unpacked: Vec<Vec<i8>> = net
            .layers
            .iter()
            .map(|l| {
                let mut w = Vec::with_capacity(l.k_in * l.n_out);
                for r in 0..l.k_in {
                    let row = &l.packed[r * l.n_words..(r + 1) * l.n_words];
                    for o in 0..l.n_out {
                        let fields = l.precision.fields_per_word();
                        w.push(crate::nce::simd::unpack_field(
                            row[o / fields],
                            l.precision,
                            o % fields,
                        ) as i8);
                    }
                }
                w
            })
            .collect();
        let sparse_idx: Vec<SparseRowIndex> = if net.sparse_weights {
            net.layers
                .iter()
                .zip(&unpacked)
                .map(|(l, w)| SparseRowIndex::build(w, l.k_in, l.n_out, l.precision))
                .collect()
        } else {
            Vec::new()
        };
        let im2col_tables = match &net.arch {
            ArchDesc::Convnet { side, channels, .. } => vec![
                im2col_table(*side, channels[0]),
                im2col_table(side / 2, channels[1]),
            ],
            _ => Vec::new(),
        };
        Self {
            net,
            unpacked,
            sparse_idx,
            im2col_tables,
            membranes,
            spike_bufs,
            input_spikes: SpikePlane::flat(input_dim),
            patch_bufs,
            pool_bufs,
            nce: NeuronComputeEngine::with_kernels(kernels),
            counts: vec![0u32; classes],
            stats: InferStats::default(),
            layer_stats: Vec::new(),
        }
    }

    /// The loaded network this engine executes.
    pub fn network(&self) -> &QuantNetwork {
        &self.net
    }

    /// The kernel backend this engine is bound to (§Perf P7) — the one
    /// handle lives on the embedded NCE.
    pub fn kernels(&self) -> Kernels {
        self.nce.kernels()
    }

    /// Stats of the most recent `infer` call.
    pub fn last_stats(&self) -> InferStats {
        self.stats
    }

    /// Per-layer activity of the most recent `infer` call (cycle-simulator
    /// workload input).
    pub fn last_layer_stats(&self) -> &[LayerStats] {
        &self.layer_stats
    }

    /// Reset all membrane state (done automatically per `infer`).
    pub fn reset(&mut self) {
        for m in &mut self.membranes {
            m.fill(0);
        }
    }

    /// Run one sample (u8 pixels) through all timesteps; returns the
    /// per-class spike counts. Argmax of the result is the prediction
    /// (first maximum on ties — same rule as `np.argmax`).
    pub fn infer(&mut self, pixels: &[u8]) -> &[u32] {
        self.infer_steps(pixels, self.net.arch.timesteps())
    }

    /// Ablation variant: run only the first `timesteps` steps (early-exit
    /// readout — the integer dynamics of a truncated run are exactly the
    /// prefix of the full run, so accuracy-vs-T curves need no re-export).
    pub fn infer_steps(&mut self, pixels: &[u8], timesteps: u32) -> &[u32] {
        let mut enc = RateEncoder::new();
        self.infer_with_encoder(pixels, timesteps, &mut enc)
    }

    /// Ablation variant: run with an arbitrary spike encoder (the
    /// deployed coding is the deterministic rate code — this is how the
    /// Poisson / TTFS comparisons in the ablation bench are produced).
    pub fn infer_with_encoder(
        &mut self,
        pixels: &[u8],
        timesteps: u32,
        encoder: &mut dyn crate::encode::SpikeEncoder,
    ) -> &[u32] {
        assert!(timesteps <= self.net.arch.timesteps(), "beyond trained T");
        self.reset();
        self.run_window(pixels, timesteps, encoder, false);
        // dense bound stays the *trained-T* budget even for truncated
        // runs (the stats contract predates early-exit readout)
        self.stats.dense_synops =
            self.net.arch.synops_per_step() * self.net.arch.timesteps() as u64;
        &self.counts
    }

    /// Early-exit classification: integrate until the readout layer
    /// first fires (or the trained `T` elapses), returning
    /// `(prediction, decision_step)` with `decision_step` the number of
    /// timesteps actually executed (`1..=T`).
    ///
    /// Bit-identity contract: the result is exactly
    /// [`infer_steps`](Self::infer_steps)`(pixels, decision_step)` — the
    /// truncation contract makes the early exit a pure latency/energy
    /// win, never a numerics change. [`last_stats`](Self::last_stats)
    /// reflects only the executed steps (`dense_synops` credits the
    /// skipped tail), which is what the energy model prices.
    pub fn infer_until_decision(&mut self, pixels: &[u8]) -> (usize, u32) {
        let trained_t = self.net.arch.timesteps();
        let mut enc = RateEncoder::new();
        self.infer_until_decision_with_encoder(pixels, trained_t, &mut enc)
    }

    /// [`infer_until_decision`](Self::infer_until_decision) with an
    /// explicit timestep budget and encoder (TTFS is the natural fit:
    /// one spike per pixel makes the first readout fire a real
    /// decision event).
    pub fn infer_until_decision_with_encoder(
        &mut self,
        pixels: &[u8],
        timesteps: u32,
        encoder: &mut dyn crate::encode::SpikeEncoder,
    ) -> (usize, u32) {
        assert!(timesteps <= self.net.arch.timesteps(), "beyond trained T");
        self.reset();
        let decision = self.run_window(pixels, timesteps, encoder, true);
        self.stats.dense_synops =
            self.net.arch.synops_per_step() * decision as u64;
        (argmax(&self.counts), decision)
    }

    /// One **streaming window**: run `steps` timesteps over `pixels`
    /// *without* resetting the membranes, returning this window's
    /// per-class spike counts.
    ///
    /// This is the temporal-workload entry point ([`crate::coordinator`]
    /// stream sessions and `lspine stream` are built on it): membrane
    /// state carries over from whatever the engine held before the call,
    /// so under [`ResetPolicy::Hold`] the LIF dynamics are exactly
    /// continuous across windows — a session replay is bit-identical to
    /// the same windows run back-to-back here, and splitting a run
    /// changes nothing but the encoder's window-local phase (each window
    /// encodes its frame from `t = 0`; with the phase carried across the
    /// split the runs are bit-identical, membranes included — see the
    /// compose test and `tests/streaming.rs`). Window lengths may be
    /// ragged and are not limited by the trained `T` — the deterministic
    /// rate code is defined for every timestep index.
    pub fn infer_window(&mut self, pixels: &[u8], steps: u32) -> &[u32] {
        let mut enc = RateEncoder::new();
        self.infer_window_with_encoder(pixels, steps, &mut enc)
    }

    /// [`infer_window`](Self::infer_window) with an explicit (possibly
    /// stateful) encoder — delta and sliding-window codings keep their
    /// frame history in the encoder, which a stream session owns
    /// alongside the membrane state.
    pub fn infer_window_with_encoder(
        &mut self,
        pixels: &[u8],
        steps: u32,
        encoder: &mut dyn crate::encode::SpikeEncoder,
    ) -> &[u32] {
        self.run_window(pixels, steps, encoder, false);
        self.stats.dense_synops = self.net.arch.synops_per_step() * steps as u64;
        &self.counts
    }

    /// Early-exit streaming window: like
    /// [`infer_window_with_encoder`](Self::infer_window_with_encoder)
    /// but the integration stops at the first readout fire. Returns the
    /// window's per-class counts plus the decision step (`1..=steps`;
    /// `steps` when the readout stayed silent). Membranes are left
    /// exactly as a fixed-`steps` run truncated at the decision step
    /// would leave them, so held sessions stay bit-reproducible.
    pub fn infer_window_until_decision_with_encoder(
        &mut self,
        pixels: &[u8],
        steps: u32,
        encoder: &mut dyn crate::encode::SpikeEncoder,
    ) -> (&[u32], u32) {
        let decision = self.run_window(pixels, steps, encoder, true);
        self.stats.dense_synops =
            self.net.arch.synops_per_step() * decision as u64;
        (&self.counts, decision)
    }

    /// Shared inference loop: up to `steps` encoded timesteps over the
    /// current membrane state (callers decide whether to
    /// [`reset`](Self::reset) first and what `dense_synops` budget to
    /// record). With `early_exit` the loop stops the moment the readout
    /// layer first fires; the return value is the number of timesteps
    /// actually executed (`steps` when the readout never fired or
    /// `early_exit` is off). Because each timestep's integer dynamics
    /// depend only on prior steps, an early-exited run is exactly the
    /// fixed-`steps` run truncated at the returned step — counts,
    /// membranes and stats included (the `infer_steps` truncation
    /// contract).
    fn run_window(
        &mut self,
        pixels: &[u8],
        steps: u32,
        encoder: &mut dyn crate::encode::SpikeEncoder,
        early_exit: bool,
    ) -> u32 {
        assert_eq!(
            encoder.encoded_len(pixels.len()),
            self.net.arch.input_dim(),
            "bad input size"
        );
        self.counts.fill(0);
        self.stats = InferStats::default();
        let positions = self.net.arch.layer_positions();
        self.layer_stats = self
            .net
            .layers
            .iter()
            .zip(&positions)
            .map(|(l, &pos)| LayerStats {
                positions: pos as u64,
                n_out: l.n_out as u64,
                n_words: l.n_words as u64,
                ..Default::default()
            })
            .collect();

        let mut executed = 0u32;
        for t in 0..steps {
            encoder.encode_step_plane(pixels, t, &mut self.input_spikes);
            match self.net.arch {
                ArchDesc::Mlp { .. } => self.step_mlp(),
                ArchDesc::Convnet { .. } => self.step_conv(),
            }
            let last = self.spike_bufs.last().unwrap();
            let counts = &mut self.counts;
            let mut fired = false;
            last.for_each_set(|c| {
                counts[c] += 1;
                fired = true;
            });
            executed = t + 1;
            if early_exit && fired {
                break;
            }
        }
        executed
    }

    /// A zeroed membrane snapshot with this engine's layer shapes — what
    /// a new stream session starts from.
    pub fn fresh_state(&self) -> MembraneState {
        MembraneState {
            layers: self.membranes.iter().map(|m| vec![0i32; m.len()]).collect(),
        }
    }

    /// Exchange the engine's membrane state with `state` (both directions,
    /// allocation-free). The serving hot path runs one engine per worker
    /// across many sessions: swap a session's state in, run its window,
    /// swap back out. Panics if the snapshot's shapes do not match this
    /// engine's architecture.
    pub fn swap_state(&mut self, state: &mut MembraneState) {
        assert_eq!(state.layers.len(), self.membranes.len(), "layer count mismatch");
        for (mine, theirs) in self.membranes.iter_mut().zip(&mut state.layers) {
            assert_eq!(mine.len(), theirs.len(), "membrane shape mismatch");
            std::mem::swap(mine, theirs);
        }
    }

    /// Apply a window-boundary [`ResetPolicy`] to the current membranes
    /// (called between windows of a stream, never inside one).
    pub fn apply_boundary(&mut self, policy: ResetPolicy) {
        match policy {
            ResetPolicy::Hold => {}
            ResetPolicy::Reset => self.reset(),
            ResetPolicy::Decay(shift) => {
                for m in &mut self.membranes {
                    NeuronComputeEngine::decay_membranes(m, shift);
                }
            }
        }
    }

    /// Argmax prediction for one sample.
    pub fn predict(&mut self, pixels: &[u8]) -> usize {
        self.infer(pixels);
        argmax(&self.counts)
    }

    fn step_mlp(&mut self) {
        let leak = self.net.arch.leak_shift();
        let n_layers = self.net.layers.len();
        for i in 0..n_layers {
            let layer = &self.net.layers[i];
            let params = LifParams::new(layer.theta, leak);
            // split borrows: input spikes come from the previous plane
            let (prev, rest) = if i == 0 {
                (&self.input_spikes, &mut self.spike_bufs[..])
            } else {
                let (a, b) = self.spike_bufs.split_at_mut(i);
                (&a[i - 1], b)
            };
            let out = &mut rest[0]; // == spike_bufs[i]
            match self.sparse_idx.get(i) {
                Some(sidx) => self.nce.step_plane_sparse(
                    prev.words(),
                    layer.k_in,
                    &self.unpacked[i],
                    sidx,
                    layer.precision,
                    &mut self.membranes[i],
                    out.words_mut(),
                    params,
                ),
                None => self.nce.step_plane_unpacked(
                    prev.words(),
                    layer.k_in,
                    &self.unpacked[i],
                    layer.n_words,
                    layer.precision,
                    &mut self.membranes[i],
                    out.words_mut(),
                    params,
                ),
            }
            let spikes = out.count_ones();
            self.stats.active_rows += self.nce.last_active_rows() as u64;
            self.stats.words_touched += self.nce.last_words_touched() as u64;
            self.stats.spikes_emitted += spikes;
            let ls = &mut self.layer_stats[i];
            ls.active_rows += self.nce.last_active_rows() as u64;
            ls.words_touched += self.nce.last_words_touched() as u64;
            ls.spikes_emitted += spikes;
        }
    }

    fn step_conv(&mut self) {
        let (side, channels, classes) = match &self.net.arch {
            ArchDesc::Convnet { side, channels, classes, .. } => {
                (*side, channels.clone(), *classes)
            }
            _ => unreachable!(),
        };
        let leak = self.net.arch.leak_shift();
        let (c0, c1, c2) = (channels[0], channels[1], channels[2]);
        let s2 = side / 2;
        let s4 = side / 4;
        let kernels = self.nce.kernels(); // Copy: frees `self` for buffer borrows

        // ---- conv1: input plane [side,side,c0] -> spikes [side,side,c1]
        kernels.gather_plane(
            self.input_spikes.words(),
            &self.im2col_tables[0],
            &mut self.patch_bufs[0],
        );
        self.lif_conv_layer(0, side * side, 9 * c0, leak);

        // ---- pool1 (word-wide OR): [side,side,c1] -> flat [s2,s2,c1]
        kernels.maxpool2_plane(&self.spike_bufs[0], side, c1, &mut self.pool_bufs[0]);

        // ---- conv2 over pooled plane [s2,s2,c1] -> [s2,s2,c2]
        kernels.gather_plane(
            self.pool_bufs[0].words(),
            &self.im2col_tables[1],
            &mut self.patch_bufs[1],
        );
        self.lif_conv_layer(1, s2 * s2, 9 * c1, leak);

        // ---- pool2 (word-wide OR): [s2,s2,c2] -> flat [s4,s4,c2]
        kernels.maxpool2_plane(&self.spike_bufs[1], s2, c2, &mut self.pool_bufs[1]);
        let fc_in = s4 * s4 * c2;
        let _ = classes;

        // ---- fc (event scan straight over the flat pool plane)
        let layer = &self.net.layers[2];
        let params = LifParams::new(layer.theta, leak);
        match self.sparse_idx.get(2) {
            Some(sidx) => self.nce.step_plane_sparse(
                self.pool_bufs[1].words(),
                fc_in,
                &self.unpacked[2],
                sidx,
                layer.precision,
                &mut self.membranes[2],
                self.spike_bufs[2].words_mut(),
                params,
            ),
            None => self.nce.step_plane_unpacked(
                self.pool_bufs[1].words(),
                fc_in,
                &self.unpacked[2],
                layer.n_words,
                layer.precision,
                &mut self.membranes[2],
                self.spike_bufs[2].words_mut(),
                params,
            ),
        }
        let spikes = self.spike_bufs[2].count_ones();
        self.stats.active_rows += self.nce.last_active_rows() as u64;
        self.stats.words_touched += self.nce.last_words_touched() as u64;
        self.stats.spikes_emitted += spikes;
        let ls = &mut self.layer_stats[2];
        ls.active_rows += self.nce.last_active_rows() as u64;
        ls.words_touched += self.nce.last_words_touched() as u64;
        ls.spikes_emitted += spikes;
    }

    /// Run LIF layer `idx` over `positions` word-aligned patch rows of
    /// `row_k` inputs each.
    fn lif_conv_layer(&mut self, idx: usize, positions: usize, row_k: usize, leak: u32) {
        let layer = &self.net.layers[idx];
        debug_assert_eq!(layer.k_in, row_k);
        let n = layer.n_out;
        let params = LifParams::new(layer.theta, leak);
        let mut active = 0u64;
        let mut words = 0u64;
        let mut spikes = 0u64;
        let patch = &self.patch_bufs[idx];
        let w = &self.unpacked[idx];
        let sidx = self.sparse_idx.get(idx);
        let v_all = &mut self.membranes[idx];
        let out_plane = &mut self.spike_bufs[idx];
        let nce = &mut self.nce;
        for pos in 0..positions {
            let v = &mut v_all[pos * n..(pos + 1) * n];
            let out = out_plane.pos_words_mut(pos);
            match sidx {
                Some(sidx) => nce.step_plane_sparse(
                    patch.pos_words(pos),
                    row_k,
                    w,
                    sidx,
                    layer.precision,
                    v,
                    out,
                    params,
                ),
                None => nce.step_plane_unpacked(
                    patch.pos_words(pos),
                    row_k,
                    w,
                    layer.n_words,
                    layer.precision,
                    v,
                    out,
                    params,
                ),
            }
            active += nce.last_active_rows() as u64;
            words += nce.last_words_touched() as u64;
            spikes += out.iter().map(|x| x.count_ones() as u64).sum::<u64>();
        }
        self.stats.active_rows += active;
        self.stats.words_touched += words;
        self.stats.spikes_emitted += spikes;
        let ls = &mut self.layer_stats[idx];
        ls.active_rows += active;
        ls.words_touched += words;
        ls.spikes_emitted += spikes;
    }

    /// Evaluate top-1 accuracy over a loaded LSPD dataset.
    pub fn accuracy(&mut self, data: &super::io::Dataset) -> f64 {
        let mut hits = 0usize;
        for i in 0..data.n {
            if self.predict(data.sample(i)) == data.labels[i] as usize {
                hits += 1;
            }
        }
        hits as f64 / data.n as f64
    }
}

/// First-maximum argmax (ties resolve to the lowest index, like numpy).
///
/// Generic so every consumer of spike counts — the engine (`u32`), the
/// serving layer (`i32`), the stream CLI's window aggregation (`i64`) —
/// shares the one tie-break rule.
pub fn argmax<T: PartialOrd>(xs: &[T]) -> usize {
    let mut best = 0usize;
    for (i, x) in xs.iter().enumerate().skip(1) {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

/// im2col for 3x3 SAME convolution on a channel-last plane.
///
/// Input `[side, side, ch]` (row-major y, x, c); output rows are spatial
/// positions (y*side + x), each row `9*ch` features ordered
/// `c*9 + ky*3 + kx` — pinned to `lax.conv_general_dilated_patches`.
pub fn im2col(plane: &[u8], side: usize, ch: usize, out: &mut [u8]) {
    let row_k = 9 * ch;
    debug_assert!(out.len() >= side * side * row_k);
    debug_assert_eq!(plane.len(), side * side * ch);
    for y in 0..side {
        for x in 0..side {
            let row = &mut out[(y * side + x) * row_k..(y * side + x + 1) * row_k];
            for c in 0..ch {
                for ky in 0..3usize {
                    let sy = y as isize + ky as isize - 1;
                    for kx in 0..3usize {
                        let sx = x as isize + kx as isize - 1;
                        let v = if sy >= 0
                            && sy < side as isize
                            && sx >= 0
                            && sx < side as isize
                        {
                            plane[(sy as usize * side + sx as usize) * ch + c]
                        } else {
                            0
                        };
                        row[c * 9 + ky * 3 + kx] = v;
                    }
                }
            }
        }
    }
}

/// Precomputed im2col gather table: `table[pos*9*ch + f]` is the source
/// index into the plane, or `u32::MAX` for zero padding (§Perf P4 — the
/// border tests move out of the per-timestep loop into construction).
pub fn im2col_table(side: usize, ch: usize) -> Vec<u32> {
    let row_k = 9 * ch;
    let mut table = vec![u32::MAX; side * side * row_k];
    for y in 0..side {
        for x in 0..side {
            let base = (y * side + x) * row_k;
            for c in 0..ch {
                for ky in 0..3usize {
                    let sy = y as isize + ky as isize - 1;
                    for kx in 0..3usize {
                        let sx = x as isize + kx as isize - 1;
                        if sy >= 0 && sy < side as isize && sx >= 0 && sx < side as isize
                        {
                            table[base + c * 9 + ky * 3 + kx] =
                                ((sy as usize * side + sx as usize) * ch + c) as u32;
                        }
                    }
                }
            }
        }
    }
    table
}

/// Table-driven im2col: one flat gather, no border branches.
pub fn im2col_gather(plane: &[u8], table: &[u32], out: &mut [u8]) {
    for (o, &idx) in out.iter_mut().zip(table) {
        *o = if idx == u32::MAX { 0 } else { plane[idx as usize] };
    }
}

/// 2x2 max pool (OR on binary spikes), channel-last.
/// `[side, side, ch]` -> `[side/2, side/2, ch]`.
pub fn maxpool2(plane: &[u8], side: usize, ch: usize, out: &mut [u8]) {
    let half = side / 2;
    debug_assert!(out.len() >= half * half * ch);
    for y in 0..half {
        for x in 0..half {
            for c in 0..ch {
                let p = |yy: usize, xx: usize| plane[(yy * side + xx) * ch + c];
                let m = p(2 * y, 2 * x)
                    .max(p(2 * y, 2 * x + 1))
                    .max(p(2 * y + 1, 2 * x))
                    .max(p(2 * y + 1, 2 * x + 1));
                out[(y * half + x) * ch + c] = m;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::QuantNetLayer;
    use crate::nce::simd::{pack_row, Precision};

    fn dense_layer(
        k: usize,
        n: usize,
        p: Precision,
        f: impl Fn(usize, usize) -> i32,
        theta: i32,
    ) -> QuantNetLayer {
        let mut packed = Vec::new();
        let n_words = n.div_ceil(p.fields_per_word());
        for j in 0..k {
            let row: Vec<i32> = (0..n).map(|o| f(j, o)).collect();
            packed.extend(pack_row(&row, p));
        }
        QuantNetLayer {
            precision: p,
            k_in: k,
            n_out: n,
            n_words,
            scale: 1.0,
            theta,
            packed,
        }
    }

    fn tiny_mlp() -> QuantNetwork {
        let arch = ArchDesc::Mlp { sizes: vec![4, 3, 2], timesteps: 4, leak_shift: 2 };
        let l0 = dense_layer(4, 3, Precision::Int4, |j, o| ((j + o) % 3) as i32, 2);
        let l1 = dense_layer(3, 2, Precision::Int4, |j, o| j as i32 - o as i32, 1);
        QuantNetwork { arch, layers: vec![l0, l1], sparse_weights: false }
    }

    #[test]
    fn mlp_inference_runs_and_is_deterministic() {
        let mut e = SnnEngine::new(tiny_mlp());
        let a = e.infer(&[255, 128, 0, 200]).to_vec();
        let b = e.infer(&[255, 128, 0, 200]).to_vec();
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| c <= 4)); // bounded by timesteps
    }

    #[test]
    fn stats_populated() {
        let mut e = SnnEngine::new(tiny_mlp());
        e.infer(&[255, 255, 255, 255]);
        let s = e.last_stats();
        assert!(s.active_rows > 0);
        assert!(s.dense_synops > 0);
        assert!(s.words_touched >= s.active_rows); // >= 1 word per row
    }

    #[test]
    fn zero_input_zero_spikes() {
        let mut e = SnnEngine::new(tiny_mlp());
        let counts = e.infer(&[0, 0, 0, 0]).to_vec();
        assert!(counts.iter().all(|&c| c == 0));
        assert_eq!(e.last_stats().active_rows, 0);
    }

    /// Rate code with its timestep index shifted by a fixed offset —
    /// emulates carrying the encoder phase across a window split.
    struct OffsetRate(u32);

    impl crate::encode::SpikeEncoder for OffsetRate {
        fn encode_step(&mut self, pixels: &[u8], t: u32, out: &mut [u8]) {
            for (o, &x) in out.iter_mut().zip(pixels) {
                *o = crate::encode::RateEncoder::spike_at(x, t + self.0);
            }
        }

        fn encode_step_plane(
            &mut self,
            pixels: &[u8],
            t: u32,
            out: &mut crate::nce::SpikePlane,
        ) {
            let off = self.0;
            out.fill_from_fn(|j| {
                crate::encode::RateEncoder::spike_at(pixels[j], t + off) != 0
            });
        }

        fn expected_count(&self, pixel: u8, t_steps: u32) -> u32 {
            ((pixel as u32) * (self.0 + t_steps) >> 8)
                - ((pixel as u32) * self.0 >> 8)
        }
    }

    #[test]
    fn held_windows_compose_bit_exactly() {
        // The Hold contract is about the *dynamics*, not the encoder:
        // membranes carry over untouched, so splitting a run into ragged
        // windows changes nothing except the rate code's window-local
        // phase (every window encodes a fresh frame from t = 0 by
        // design). Carrying the phase across the split — the offset
        // encoder below — must therefore reproduce one long run exactly:
        // identical summed counts AND identical final membranes.
        let pixels = [255u8, 128, 64, 200];
        let mut a = SnnEngine::new(tiny_mlp());
        let mut b = SnnEngine::new(tiny_mlp());
        a.reset();
        b.reset();
        let mut summed = vec![0u32; 2];
        let mut off = 0u32;
        for steps in [2u32, 1, 3] {
            let counts = a
                .infer_window_with_encoder(&pixels, steps, &mut OffsetRate(off))
                .to_vec();
            for (s, c) in summed.iter_mut().zip(counts) {
                *s += c;
            }
            off += steps;
        }
        let full = b.infer_window(&pixels, 6).to_vec();
        assert_eq!(summed, full);
        let (mut sa, mut sb) = (a.fresh_state(), b.fresh_state());
        a.swap_state(&mut sa);
        b.swap_state(&mut sb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn swap_state_isolates_sessions() {
        // interleaving unrelated one-shot inferences between a session's
        // windows must not perturb the session (snapshot/restore).
        let pixels = [10u8, 250, 90, 170];
        let mut clean = SnnEngine::new(tiny_mlp());
        clean.reset();
        let w1 = clean.infer_window(&pixels, 3).to_vec();
        let w2 = clean.infer_window(&pixels, 3).to_vec();

        let mut shared = SnnEngine::new(tiny_mlp());
        let mut session = shared.fresh_state();
        shared.swap_state(&mut session);
        let i1 = shared.infer_window(&pixels, 3).to_vec();
        shared.swap_state(&mut session); // park the session
        shared.infer(&[255, 255, 255, 255]); // unrelated traffic
        shared.swap_state(&mut session); // resume
        let i2 = shared.infer_window(&pixels, 3).to_vec();
        assert_eq!((i1, i2), (w1, w2));
    }

    #[test]
    fn boundary_policies() {
        let pixels = [200u8, 200, 200, 200];
        let mut e = SnnEngine::new(tiny_mlp());
        e.reset();
        e.infer_window(&pixels, 4);
        // Reset: next window equals a fresh-engine window
        e.apply_boundary(ResetPolicy::Reset);
        let after_reset = e.infer_window(&pixels, 4).to_vec();
        let mut fresh = SnnEngine::new(tiny_mlp());
        fresh.reset();
        assert_eq!(after_reset, fresh.infer_window(&pixels, 4).to_vec());
        // Decay: membranes shrink by exactly v >> k
        e.reset();
        e.infer_window(&pixels, 1);
        let mut snap = e.fresh_state();
        e.swap_state(&mut snap); // extract...
        let before = snap.clone();
        e.swap_state(&mut snap); // ...and put back
        e.apply_boundary(ResetPolicy::Decay(1));
        let mut after = e.fresh_state();
        e.swap_state(&mut after);
        for (b, a) in before.layers().iter().zip(after.layers()) {
            for (&vb, &va) in b.iter().zip(a) {
                assert_eq!(va, vb - (vb >> 1));
            }
        }
    }

    #[test]
    fn reset_policy_parsing() {
        assert_eq!(ResetPolicy::parse("hold"), Some(ResetPolicy::Hold));
        assert_eq!(ResetPolicy::parse("RESET"), Some(ResetPolicy::Reset));
        assert_eq!(ResetPolicy::parse("decay:3"), Some(ResetPolicy::Decay(3)));
        assert_eq!(ResetPolicy::parse("decay:40"), None);
        // shift 0 zeroes the membranes — that is `reset`, not a decay
        assert_eq!(ResetPolicy::parse("decay:0"), None);
        assert_eq!(ResetPolicy::parse("decay:"), None);
        assert_eq!(ResetPolicy::parse("melt"), None);
        assert_eq!(ResetPolicy::Decay(2).name(), "decay:2");
    }

    #[test]
    fn early_exit_is_truncated_fixed_t() {
        // the early-exit run must be byte-identical to the fixed-T run
        // truncated at decision_step: counts, membranes, stats
        for pixels in [[255u8, 128, 64, 200], [40, 40, 40, 40], [0, 0, 0, 0]] {
            let mut a = SnnEngine::new(tiny_mlp());
            let (pred, step) = a.infer_until_decision(&pixels);
            assert!(step >= 1 && step <= 4, "decision_step={step}");
            let counts_a = a.counts.clone();
            let mut sa = a.fresh_state();
            a.swap_state(&mut sa);

            let mut b = SnnEngine::new(tiny_mlp());
            let counts_b = b.infer_steps(&pixels, step).to_vec();
            assert_eq!(counts_a, counts_b, "pixels={pixels:?}");
            assert_eq!(pred, argmax(&counts_b));
            let mut sb = b.fresh_state();
            b.swap_state(&mut sb);
            assert_eq!(sa, sb, "membranes diverge at step {step}");
        }
    }

    #[test]
    fn early_exit_stops_at_first_readout_fire() {
        let pixels = [255u8, 128, 64, 200];
        let mut e = SnnEngine::new(tiny_mlp());
        let (_, step) = e.infer_until_decision(&pixels);
        // the step it stopped at really is the first with readout output
        let mut f = SnnEngine::new(tiny_mlp());
        for t in 1..step {
            let c: u32 = f.infer_steps(&pixels, t).iter().sum();
            assert_eq!(c, 0, "readout fired before the decision step");
        }
        let at: u32 = f.infer_steps(&pixels, step).iter().sum();
        assert!(at > 0 || step == 4, "no fire at the decision step");
        // silent input: never fires, decision_step == the full budget,
        // dense_synops credits nothing (all steps ran)
        let (_, silent) = e.infer_until_decision(&[0, 0, 0, 0]);
        assert_eq!(silent, 4);
        // energy credit: an early decision prices fewer dense synops
        e.infer_until_decision(&pixels);
        let early = e.last_stats().dense_synops;
        e.infer(&pixels);
        let full = e.last_stats().dense_synops;
        assert_eq!(
            early,
            full / 4 * step as u64,
            "dense_synops must scale with decision_step"
        );
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1, 3, 3, 2]), 1);
        assert_eq!(argmax(&[5]), 0);
        assert_eq!(argmax(&[0, 0, 0]), 0);
    }

    #[test]
    fn im2col_matches_python_ordering() {
        // mirror of the python pin: 4x4, 2 channels, value 100c + 10y + x
        // (values here clipped to u8; use small side to stay in range)
        let side = 4;
        let ch = 2;
        let mut plane = vec![0u8; side * side * ch];
        for c in 0..ch {
            for y in 0..side {
                for x in 0..side {
                    plane[(y * side + x) * ch + c] = (100 * c + 10 * y + x) as u8;
                }
            }
        }
        let mut out = vec![0u8; side * side * 9 * ch];
        im2col(&plane, side, ch, &mut out);
        let row = &out[(1 * side + 1) * 18..(1 * side + 1 + 1) * 18];
        // expected from python: [0,1,2,10,11,12,20,21,22,100,...,122]
        assert_eq!(
            row,
            &[0, 1, 2, 10, 11, 12, 20, 21, 22, 100, 101, 102, 110, 111, 112, 120, 121, 122]
        );
    }

    #[test]
    fn im2col_gather_matches_direct() {
        // §Perf P4 table-driven gather == the branchy reference
        for (side, ch) in [(4usize, 2usize), (8, 1), (8, 16), (16, 1)] {
            let plane: Vec<u8> =
                (0..side * side * ch).map(|i| (i * 37 % 251) as u8).collect();
            let mut a = vec![0u8; side * side * 9 * ch];
            let mut b = vec![0u8; side * side * 9 * ch];
            im2col(&plane, side, ch, &mut a);
            let table = im2col_table(side, ch);
            im2col_gather(&plane, &table, &mut b);
            assert_eq!(a, b, "side={side} ch={ch}");
        }
    }

    #[test]
    fn im2col_zero_pads_borders() {
        let side = 3;
        let plane = vec![1u8; side * side];
        let mut out = vec![0u8; side * side * 9];
        im2col(&plane, side, 1, &mut out);
        // top-left position: ky=0 and kx=0 taps out of range -> 0
        let row = &out[0..9];
        assert_eq!(row, &[0, 0, 0, 0, 1, 1, 0, 1, 1]);
    }

    #[test]
    fn maxpool_is_or() {
        let plane = vec![
            0, 1, 0, 0, //
            0, 0, 0, 0, //
            1, 1, 0, 0, //
            1, 1, 0, 0,
        ];
        let mut out = vec![0u8; 4];
        maxpool2(&plane, 4, 1, &mut out);
        assert_eq!(out, vec![1, 0, 1, 0]);
    }
}
