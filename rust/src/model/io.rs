//! Binary artifact readers — LSPW weights, LSPD datasets, JSON manifest.
//!
//! Formats are defined by `python/compile/model.py` (write side); this is
//! the read side. All integers little-endian. Readers validate magics,
//! versions and payload sizes and fail loudly on mismatch.

use std::collections::BTreeMap;
use std::path::Path;

use crate::nce::simd::Precision;
use crate::util::json::{self, Value};
use crate::Result;

use super::network::{ArchDesc, QuantNetLayer, QuantNetwork};

// Shared with the write side in `crate::forge` — one definition keeps
// reader and writer in lockstep across version bumps.
pub(crate) const WEIGHTS_MAGIC: &[u8; 4] = b"LSPW";
pub(crate) const DATASET_MAGIC: &[u8; 4] = b"LSPD";
pub(crate) const STREAM_MAGIC: &[u8; 4] = b"LSPS";
pub(crate) const FORMAT_VERSION: u32 = 1;
/// LSPW version tag of the block-sparse row encoding (pruned weights).
/// Only LSPW files use it; LSPD/LSPS/manifest stay at [`FORMAT_VERSION`].
pub(crate) const SPARSE_FORMAT_VERSION: u32 = 2;

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            anyhow::bail!("truncated artifact: need {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
}

/// Load an LSPW packed-weights file into a [`QuantNetwork`].
///
/// The arch description comes from the manifest; the loader validates the
/// weight shapes against it via [`QuantNetwork::validate`].
///
/// Two on-disk layouts share the magic and are told apart by version:
///
/// * **v1 (dense)** — per layer, `u32 packed[k_in * n_words]` row-major.
///   Byte-identical to every artifact written before sparse support.
/// * **v2 (block-sparse rows)** — per layer, after the same header, a
///   `u32 bitmap[k_in * ceil(n_words/32)]` (bit `b` of row `r`'s bitmap
///   span set ⇔ packed word `b` of row `r` is nonzero) followed by
///   `u32 payload[popcount(bitmap)]` holding exactly the nonzero packed
///   words, row-major then word-index order. The loader reconstructs the
///   dense `packed` array (absent words are zero) and marks the network
///   [`QuantNetwork::sparse_weights`] so the engine builds skip indices.
pub fn load_weights(path: impl AsRef<Path>, arch: ArchDesc) -> Result<QuantNetwork> {
    let blob = std::fs::read(&path)?;
    let mut c = Cursor::new(&blob);
    if c.bytes(4)? != WEIGHTS_MAGIC {
        anyhow::bail!("{}: not an LSPW file", path.as_ref().display());
    }
    let version = c.u32()?;
    let sparse = version == SPARSE_FORMAT_VERSION;
    if version != FORMAT_VERSION && !sparse {
        anyhow::bail!("unsupported LSPW version {version}");
    }
    let n_layers = c.u32()? as usize;
    let timesteps = c.u32()?;
    let leak_shift = c.u32()?;
    if timesteps != arch.timesteps() || leak_shift != arch.leak_shift() {
        anyhow::bail!(
            "weights T={timesteps}/k={leak_shift} disagree with arch T={}/k={}",
            arch.timesteps(),
            arch.leak_shift()
        );
    }

    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let bits = c.u32()?;
        let k_in = c.u32()? as usize;
        let n_out = c.u32()? as usize;
        let n_words = c.u32()? as usize;
        let scale = c.f32()?;
        let theta = c.i32()?;
        let precision = Precision::from_bits(bits)
            .ok_or_else(|| anyhow::anyhow!("bad field width {bits}"))?;
        let packed: Vec<u32> = if sparse {
            read_sparse_rows(&mut c, k_in, n_words)?
        } else {
            c.bytes(k_in * n_words * 4)?
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .collect()
        };
        if theta < 1 {
            anyhow::bail!("non-positive folded threshold {theta}");
        }
        layers.push(QuantNetLayer {
            precision,
            k_in,
            n_out,
            n_words,
            scale,
            theta,
            packed,
        });
    }
    if c.pos != blob.len() {
        anyhow::bail!("trailing bytes in LSPW file");
    }
    let net = QuantNetwork { arch, layers, sparse_weights: sparse };
    net.validate()?;
    Ok(net)
}

/// Decode one v2 layer's block-sparse rows back into the dense
/// `[k_in][n_words]` packed array.
///
/// The encoding is canonical: a set bitmap bit must carry a *nonzero*
/// payload word, bits past `n_words` in a row's last bitmap word must be
/// clear, and the payload length is exactly the bitmap popcount — any
/// violation is a loud error, so a v2 file has one valid byte form.
fn read_sparse_rows(c: &mut Cursor<'_>, k_in: usize, n_words: usize) -> Result<Vec<u32>> {
    let bm_words = n_words.div_ceil(32);
    let bitmap: Vec<u32> = c
        .bytes(k_in * bm_words * 4)?
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let nnz: usize = bitmap.iter().map(|w| w.count_ones() as usize).sum();
    let payload = c.bytes(nnz * 4)?;
    let mut payload_words =
        payload.chunks_exact(4).map(|b| u32::from_le_bytes(b.try_into().unwrap()));
    let mut packed = vec![0u32; k_in * n_words];
    for r in 0..k_in {
        for (i, &bm) in bitmap[r * bm_words..(r + 1) * bm_words].iter().enumerate() {
            let base = i * 32;
            if base + 32 > n_words && (bm >> (n_words - base)) != 0 {
                anyhow::bail!("sparse bitmap sets a word past n_words in row {r}");
            }
            let mut rest = bm;
            while rest != 0 {
                let b = rest.trailing_zeros() as usize;
                rest &= rest - 1;
                let w = payload_words.next().expect("payload sized from popcount");
                if w == 0 {
                    anyhow::bail!("zero payload word under a set bitmap bit (row {r})");
                }
                packed[r * n_words + base + b] = w;
            }
        }
    }
    Ok(packed)
}

/// A loaded LSPD dataset: u8 pixels (encoder input) + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Samples in the set.
    pub n: usize,
    /// Pixels per sample.
    pub dim: usize,
    /// Label alphabet size.
    pub classes: usize,
    /// Row-major `[n][dim]` u8 pixels — exactly what the encoder consumes.
    pub pixels: Vec<u8>,
    /// One label per sample.
    pub labels: Vec<u8>,
}

impl Dataset {
    /// Pixels of sample `i`.
    pub fn sample(&self, i: usize) -> &[u8] {
        &self.pixels[i * self.dim..(i + 1) * self.dim]
    }
}

/// Load an LSPD dataset file.
pub fn load_dataset(path: impl AsRef<Path>) -> Result<Dataset> {
    let blob = std::fs::read(&path)?;
    let mut c = Cursor::new(&blob);
    if c.bytes(4)? != DATASET_MAGIC {
        anyhow::bail!("{}: not an LSPD file", path.as_ref().display());
    }
    let version = c.u32()?;
    if version != FORMAT_VERSION {
        anyhow::bail!("unsupported LSPD version {version}");
    }
    let n = c.u32()? as usize;
    let dim = c.u32()? as usize;
    let classes = c.u32()? as usize;
    let pixels = c.bytes(n * dim)?.to_vec();
    let labels = c.bytes(n)?.to_vec();
    if c.pos != blob.len() {
        anyhow::bail!("trailing bytes in LSPD file");
    }
    if labels.iter().any(|&l| l as usize >= classes) {
        anyhow::bail!("label out of range");
    }
    Ok(Dataset { n, dim, classes, pixels, labels })
}

/// A loaded LSPS stream: a continuous frame sequence with one event
/// label per fixed-size frame window (the temporal/streaming workload).
///
/// Unlike [`Dataset`] samples, frames are *ordered* — the signal is
/// quasi-periodic (ECG-like) and classification context accumulates in
/// the membranes across frames (see `lspine stream` and
/// [`crate::coordinator::session`]).
#[derive(Debug, Clone)]
pub struct StreamData {
    /// Total frames in the stream.
    pub frames: usize,
    /// Channels per frame (equals the models' `input_dim`).
    pub dim: usize,
    /// Event label alphabet size.
    pub classes: usize,
    /// Frames per labeled window (`frames` is a multiple of this).
    pub window: usize,
    /// Row-major `[frames][dim]` u8 channel values.
    pub pixels: Vec<u8>,
    /// One event label per window (`frames / window` entries).
    pub labels: Vec<u8>,
}

impl StreamData {
    /// Frame `i` as an encoder-input slice.
    pub fn frame(&self, i: usize) -> &[u8] {
        &self.pixels[i * self.dim..(i + 1) * self.dim]
    }

    /// Labeled windows in the stream.
    pub fn windows(&self) -> usize {
        self.frames / self.window
    }
}

/// Load an LSPS stream file.
///
/// ```text
/// magic "LSPS" | u32 version | u32 frames | u32 dim | u32 classes | u32 window
/// u8 pixels[frames * dim] | u8 labels[frames / window]
/// ```
pub fn load_stream(path: impl AsRef<Path>) -> Result<StreamData> {
    let blob = std::fs::read(&path)?;
    parse_stream(&blob)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
}

/// Parse LSPS bytes (the stdin half of `lspine stream --input -`).
pub fn parse_stream(blob: &[u8]) -> Result<StreamData> {
    let mut c = Cursor::new(blob);
    if c.bytes(4)? != STREAM_MAGIC {
        anyhow::bail!("not an LSPS stream");
    }
    let version = c.u32()?;
    if version != FORMAT_VERSION {
        anyhow::bail!("unsupported LSPS version {version}");
    }
    let frames = c.u32()? as usize;
    let dim = c.u32()? as usize;
    let classes = c.u32()? as usize;
    let window = c.u32()? as usize;
    if window == 0 || frames % window != 0 {
        anyhow::bail!("stream frames ({frames}) not a multiple of window ({window})");
    }
    let pixels = c.bytes(frames * dim)?.to_vec();
    let labels = c.bytes(frames / window)?.to_vec();
    if c.pos != blob.len() {
        anyhow::bail!("trailing bytes in LSPS file");
    }
    if labels.iter().any(|&l| l as usize >= classes) {
        anyhow::bail!("stream label out of range");
    }
    Ok(StreamData { frames, dim, classes, window, pixels, labels })
}

// ---------------------------------------------------------------------
// Manifest (JSON)
// ---------------------------------------------------------------------

/// Per-(scheme, bits) quantization record (Fig. 4 / Fig. 5 source data).
#[derive(Debug, Clone)]
pub struct QuantEntry {
    /// Top-1 accuracy on the shared test set.
    pub accuracy: f64,
    /// Packed weight footprint (Fig. 4 x-axis).
    pub memory_bits: u64,
    /// LSPW file name, relative to the artifacts directory.
    pub weights: String,
    /// Per-layer dequantization scales.
    pub scales: Vec<f32>,
    /// Per-layer folded integer thresholds.
    pub thetas: Vec<i32>,
}

#[derive(Debug, Clone)]
/// Training-run metadata recorded by the author path.
pub struct TrainingInfo {
    /// Optimizer steps trained.
    pub steps: u32,
    /// Sampled training-loss curve.
    pub loss_curve: Vec<f64>,
    /// Float-model train accuracy.
    pub fp32_train_acc: f64,
    /// Float-model test accuracy (the Fig. 4/5 baseline).
    pub fp32_test_acc: f64,
}

#[derive(Debug, Clone)]
/// The float baseline's artifact record.
pub struct Fp32Info {
    /// FP32 weight footprint.
    pub memory_bits: u64,
    /// batch size -> HLO artifact file name
    pub hlo: BTreeMap<usize, String>,
}

/// Layer-adaptive precision artifact (the paper's future-work feature).
#[derive(Debug, Clone)]
pub struct MixedEntry {
    /// Field width chosen per layer.
    pub bits_per_layer: Vec<u32>,
    /// Top-1 accuracy on the shared test set.
    pub accuracy: f64,
    /// Packed weight footprint.
    pub memory_bits: u64,
    /// LSPW file name, relative to the artifacts directory.
    pub weights: String,
    /// batch size -> HLO artifact file name
    pub hlo: BTreeMap<usize, String>,
}

#[derive(Debug, Clone)]
/// One model's manifest entry (arch + training + per-scheme artifacts).
pub struct ModelEntry {
    /// Architecture topology.
    pub arch: ArchDesc,
    /// Training metadata.
    pub training: TrainingInfo,
    /// Float baseline record.
    pub fp32: Fp32Info,
    /// scheme -> bits -> entry
    pub quant: BTreeMap<String, BTreeMap<u32, QuantEntry>>,
    /// bits -> batch size -> HLO artifact file name
    pub hlo: BTreeMap<u32, BTreeMap<usize, String>>,
    /// Layer-adaptive precision artifact, when exported.
    pub mixed: Option<MixedEntry>,
}

impl ModelEntry {
    /// The (scheme, bits) quantization record, or a loud error.
    pub fn quant_entry(&self, scheme: &str, bits: u32) -> Result<&QuantEntry> {
        self.quant
            .get(scheme)
            .and_then(|m| m.get(&bits))
            .ok_or_else(|| anyhow::anyhow!("no quant entry for {scheme}/INT{bits}"))
    }

    /// HLO artifact file for (bits, batch), or a loud error.
    pub fn hlo_file(&self, bits: u32, batch: usize) -> Result<&str> {
        self.hlo
            .get(&bits)
            .and_then(|m| m.get(&batch))
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("no HLO artifact for INT{bits} batch {batch}"))
    }
}

/// Manifest record of the shared test dataset.
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    /// LSPD file name, relative to the artifacts directory.
    pub file: String,
    /// Test-set size.
    pub n_test: usize,
    /// Pixels per sample.
    pub input_dim: usize,
    /// Label alphabet size.
    pub classes: usize,
}

/// Manifest record of the forged streaming dataset (absent in manifests
/// written before the streaming workload existed — the loader accepts
/// both).
#[derive(Debug, Clone)]
pub struct StreamInfo {
    /// LSPS file name, relative to the artifacts directory.
    pub file: String,
    /// Total frames in the stream.
    pub frames: usize,
    /// Frames per labeled window.
    pub window: usize,
    /// Event label alphabet size.
    pub classes: usize,
}

/// The artifact manifest — everything the runtime needs to find/load.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Format version shared by every artifact kind.
    pub format_version: u32,
    /// The shared test dataset.
    pub dataset: DatasetInfo,
    /// The streaming dataset, when forged.
    pub stream: Option<StreamInfo>,
    /// Named stream families (`ecg` / `kws` / `vib` when forged; empty
    /// in manifests written before named streams existed).
    pub streams: BTreeMap<String, StreamInfo>,
    /// Per-model entries (arch + quantization + HLO records).
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    /// The named model's entry, or a loud error.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest"))
    }

    fn from_json(v: &Value) -> Result<Self> {
        let format_version = v.req("format_version")?.as_u64().unwrap_or(0) as u32;
        let d = v.req("dataset")?;
        let dataset = DatasetInfo {
            file: d.req("file")?.as_str().unwrap_or_default().to_string(),
            n_test: d.req("n_test")?.as_u64().unwrap_or(0) as usize,
            input_dim: d.req("input_dim")?.as_u64().unwrap_or(0) as usize,
            classes: d.req("classes")?.as_u64().unwrap_or(0) as usize,
        };
        let stream_info = |s: &Value| -> Result<StreamInfo> {
            Ok(StreamInfo {
                file: s.req("file")?.as_str().unwrap_or_default().to_string(),
                frames: s.req("frames")?.as_u64().unwrap_or(0) as usize,
                window: s.req("window")?.as_u64().unwrap_or(0) as usize,
                classes: s.req("classes")?.as_u64().unwrap_or(0) as usize,
            })
        };
        let stream = match v.get("stream") {
            Some(s) => Some(stream_info(s)?),
            None => None,
        };
        let mut streams = BTreeMap::new();
        if let Some(m) = v.get("streams").and_then(|s| s.as_obj()) {
            for (name, entry) in m {
                streams.insert(name.clone(), stream_info(entry)?);
            }
        }
        let mut models = BTreeMap::new();
        for (name, entry) in v
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("models not an object"))?
        {
            models.insert(name.clone(), Self::model_from_json(entry)?);
        }
        Ok(Manifest { format_version, dataset, stream, streams, models })
    }

    fn model_from_json(v: &Value) -> Result<ModelEntry> {
        let arch = ArchDesc::from_json(v.req("arch")?)?;
        let t = v.req("training")?;
        let training = TrainingInfo {
            steps: t.req("steps")?.as_u64().unwrap_or(0) as u32,
            loss_curve: t
                .req("loss_curve")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .collect(),
            fp32_train_acc: t.req("fp32_train_acc")?.as_f64().unwrap_or(0.0),
            fp32_test_acc: t.req("fp32_test_acc")?.as_f64().unwrap_or(0.0),
        };
        let f = v.req("fp32")?;
        let mut fp32_hlo = BTreeMap::new();
        if let Some(m) = f.req("hlo")?.as_obj() {
            for (b, file) in m {
                fp32_hlo.insert(
                    b.parse::<usize>()?,
                    file.as_str().unwrap_or_default().to_string(),
                );
            }
        }
        let fp32 = Fp32Info {
            memory_bits: f.req("memory_bits")?.as_u64().unwrap_or(0),
            hlo: fp32_hlo,
        };
        let mut quant = BTreeMap::new();
        if let Some(schemes) = v.req("quant")?.as_obj() {
            for (scheme, per_bits) in schemes {
                let mut inner = BTreeMap::new();
                for (bits, e) in per_bits.as_obj().into_iter().flatten() {
                    inner.insert(
                        bits.parse::<u32>()?,
                        QuantEntry {
                            accuracy: e.req("accuracy")?.as_f64().unwrap_or(0.0),
                            memory_bits: e.req("memory_bits")?.as_u64().unwrap_or(0),
                            weights: e
                                .req("weights")?
                                .as_str()
                                .unwrap_or_default()
                                .to_string(),
                            scales: e
                                .req("scales")?
                                .as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(|x| x.as_f64().map(|f| f as f32))
                                .collect(),
                            thetas: e
                                .req("thetas")?
                                .as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(|x| x.as_i64().map(|i| i as i32))
                                .collect(),
                        },
                    );
                }
                quant.insert(scheme.clone(), inner);
            }
        }
        let mut hlo = BTreeMap::new();
        if let Some(per_prec) = v.req("hlo")?.as_obj() {
            for (prec, per_batch) in per_prec {
                let bits: u32 = prec
                    .strip_prefix("int")
                    .ok_or_else(|| anyhow::anyhow!("bad hlo key {prec:?}"))?
                    .parse()?;
                let mut inner = BTreeMap::new();
                for (b, file) in per_batch.as_obj().into_iter().flatten() {
                    inner.insert(
                        b.parse::<usize>()?,
                        file.as_str().unwrap_or_default().to_string(),
                    );
                }
                hlo.insert(bits, inner);
            }
        }
        let mixed = match v.get("mixed") {
            Some(m) => {
                let mut mhlo = BTreeMap::new();
                for (b, file) in m.req("hlo")?.as_obj().into_iter().flatten() {
                    mhlo.insert(
                        b.parse::<usize>()?,
                        file.as_str().unwrap_or_default().to_string(),
                    );
                }
                Some(MixedEntry {
                    bits_per_layer: m
                        .req("bits_per_layer")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|x| x.as_u64().map(|b| b as u32))
                        .collect(),
                    accuracy: m.req("accuracy")?.as_f64().unwrap_or(0.0),
                    memory_bits: m.req("memory_bits")?.as_u64().unwrap_or(0),
                    weights: m
                        .req("weights")?
                        .as_str()
                        .unwrap_or_default()
                        .to_string(),
                    hlo: mhlo,
                })
            }
            None => None,
        };
        Ok(ModelEntry { arch, training, fp32, quant, hlo, mixed })
    }
}

/// Load and validate `manifest.json` from the artifacts directory.
pub fn load_manifest(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
    let path = artifacts_dir.as_ref().join("manifest.json");
    let s = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("{}: {e} (run `make artifacts` first)", path.display())
    })?;
    let m = Manifest::from_json(&json::parse(&s)?)?;
    if m.format_version != FORMAT_VERSION {
        anyhow::bail!("unsupported manifest version {}", m.format_version);
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_lspw(layers: &[(u32, u32, u32, u32, f32, i32, Vec<u32>)]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(WEIGHTS_MAGIC);
        for v in [FORMAT_VERSION, layers.len() as u32, 16, 2] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        for (bits, k, n, nw, scale, theta, words) in layers {
            for v in [*bits, *k, *n, *nw] {
                b.extend_from_slice(&v.to_le_bytes());
            }
            b.extend_from_slice(&scale.to_le_bytes());
            b.extend_from_slice(&theta.to_le_bytes());
            for w in words {
                b.extend_from_slice(&w.to_le_bytes());
            }
        }
        b
    }

    fn tiny_arch() -> ArchDesc {
        ArchDesc::Mlp { sizes: vec![2, 4], timesteps: 16, leak_shift: 2 }
    }

    #[test]
    fn lspw_roundtrip() {
        let dir = std::env::temp_dir().join("lspine_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.w.bin");
        // 2 inputs x 4 outputs INT8 -> 1 word per row
        let blob = write_lspw(&[(8, 2, 4, 1, 0.5, 2, vec![0x04030201, 0x7F00FF80])]);
        std::fs::write(&p, blob).unwrap();
        let net = load_weights(&p, tiny_arch()).unwrap();
        assert_eq!(net.layers.len(), 1);
        let l = &net.layers[0];
        assert_eq!((l.k_in, l.n_out, l.n_words), (2, 4, 1));
        assert_eq!(l.scale, 0.5);
        assert_eq!(l.theta, 2);
        assert_eq!(l.packed, vec![0x04030201, 0x7F00FF80]);
        assert_eq!(net.memory_bits(), 64);
    }

    #[test]
    fn lspw_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("lspine_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(load_weights(&p, tiny_arch()).is_err());
    }

    #[test]
    fn lspw_rejects_truncated() {
        let dir = std::env::temp_dir().join("lspine_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.bin");
        let mut blob = write_lspw(&[(8, 2, 4, 1, 0.5, 2, vec![1, 2])]);
        blob.truncate(blob.len() - 3);
        std::fs::write(&p, blob).unwrap();
        assert!(load_weights(&p, tiny_arch()).is_err());
    }

    #[test]
    fn lspw_rejects_shape_mismatch() {
        let dir = std::env::temp_dir().join("lspine_io_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shape.bin");
        // arch expects (2,4) but file says (3,4)
        let blob = write_lspw(&[(8, 3, 4, 1, 0.5, 2, vec![1, 2, 3])]);
        std::fs::write(&p, blob).unwrap();
        assert!(load_weights(&p, tiny_arch()).is_err());
    }

    #[test]
    fn lspd_roundtrip() {
        let dir = std::env::temp_dir().join("lspine_io_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.bin");
        let mut b = Vec::new();
        b.extend_from_slice(DATASET_MAGIC);
        for v in [FORMAT_VERSION, 2u32, 3, 10] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&[1, 2, 3, 4, 5, 6]); // pixels
        b.extend_from_slice(&[7, 9]); // labels
        std::fs::write(&p, b).unwrap();
        let d = load_dataset(&p).unwrap();
        assert_eq!((d.n, d.dim, d.classes), (2, 3, 10));
        assert_eq!(d.sample(1), &[4, 5, 6]);
        assert_eq!(d.labels, vec![7, 9]);
    }

    #[test]
    fn lsps_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("lspine_io_test7");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.bin");
        let mut b = Vec::new();
        b.extend_from_slice(STREAM_MAGIC);
        // 4 frames x 2 channels, 3 classes, window 2 -> 2 labels
        for v in [FORMAT_VERSION, 4u32, 2, 3, 2] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]); // pixels
        b.extend_from_slice(&[0, 2]); // labels
        std::fs::write(&p, &b).unwrap();
        let s = load_stream(&p).unwrap();
        assert_eq!((s.frames, s.dim, s.classes, s.window), (4, 2, 3, 2));
        assert_eq!(s.windows(), 2);
        assert_eq!(s.frame(1), &[3, 4]);
        assert_eq!(s.labels, vec![0, 2]);

        // frames not a multiple of window
        let mut bad = Vec::new();
        bad.extend_from_slice(STREAM_MAGIC);
        for v in [FORMAT_VERSION, 3u32, 1, 2, 2] {
            bad.extend_from_slice(&v.to_le_bytes());
        }
        bad.extend_from_slice(&[1, 2, 3]);
        bad.push(0);
        let pb = dir.join("bad.bin");
        std::fs::write(&pb, &bad).unwrap();
        assert!(load_stream(&pb).is_err());
    }

    /// A one-layer v2 blob for `tiny_arch` (2 rows x 1 word, INT8):
    /// per-row bitmaps `bms`, then the packed payload words.
    fn v2_blob(bms: [u32; 2], payload: &[u32]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(WEIGHTS_MAGIC);
        for v in [SPARSE_FORMAT_VERSION, 1u32, 16, 2] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        for v in [8u32, 2, 4, 1] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&0.5f32.to_le_bytes());
        b.extend_from_slice(&2i32.to_le_bytes());
        for bm in bms {
            b.extend_from_slice(&bm.to_le_bytes());
        }
        for w in payload {
            b.extend_from_slice(&w.to_le_bytes());
        }
        b
    }

    #[test]
    fn lspw_v2_sparse_roundtrip() {
        let dir = std::env::temp_dir().join("lspine_io_test8");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.w.bin");
        // row 0 has its single word present, row 1 is all-zero
        std::fs::write(&p, v2_blob([1, 0], &[0x04030201])).unwrap();
        let net = load_weights(&p, tiny_arch()).unwrap();
        assert!(net.sparse_weights, "v2 files mark the network sparse");
        assert_eq!(net.layers[0].packed, vec![0x04030201, 0]);
    }

    #[test]
    fn lspw_v2_rejects_non_canonical() {
        let dir = std::env::temp_dir().join("lspine_io_test9");
        std::fs::create_dir_all(&dir).unwrap();
        // zero payload word under a set bitmap bit
        let p = dir.join("z.w.bin");
        std::fs::write(&p, v2_blob([1, 1], &[0x04030201, 0])).unwrap();
        assert!(load_weights(&p, tiny_arch()).is_err());
        // bitmap bit past n_words (n_words = 1, bit 1 set)
        let p2 = dir.join("oob.w.bin");
        std::fs::write(&p2, v2_blob([2, 0], &[7])).unwrap();
        assert!(load_weights(&p2, tiny_arch()).is_err());
        // payload shorter than the bitmap popcount -> truncated
        let p3 = dir.join("short.w.bin");
        std::fs::write(&p3, v2_blob([1, 1], &[7])).unwrap();
        assert!(load_weights(&p3, tiny_arch()).is_err());
    }

    #[test]
    fn lspd_rejects_bad_label() {
        let dir = std::env::temp_dir().join("lspine_io_test6");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("dl.bin");
        let mut b = Vec::new();
        b.extend_from_slice(DATASET_MAGIC);
        for v in [FORMAT_VERSION, 1u32, 1, 4] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.push(0);
        b.push(4); // label 4 >= classes 4
        std::fs::write(&p, b).unwrap();
        assert!(load_dataset(&p).is_err());
    }
}
