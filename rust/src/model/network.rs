//! Network descriptions — the rust twin of `python/compile/snn.py` archs.

use crate::nce::simd::Precision;
use crate::util::json::Value;

/// Architecture topology, parsed from the manifest's `arch` entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArchDesc {
    /// Fully-connected LIF stack; `sizes` includes input and output dims.
    Mlp {
        /// Layer widths, input first and classes last.
        sizes: Vec<usize>,
        /// Inference timesteps the network was trained for.
        timesteps: u32,
        /// LIF leak shift shared by all layers.
        leak_shift: u32,
    },
    /// conv3x3 -> pool2 -> conv3x3 -> pool2 -> fc (all layers LIF).
    Convnet {
        /// Input plane side (square, channel-last).
        side: usize,
        /// Channels: input, after conv1, after conv2.
        channels: Vec<usize>,
        /// Output classes of the final fc layer.
        classes: usize,
        /// Inference timesteps the network was trained for.
        timesteps: u32,
        /// LIF leak shift shared by all layers.
        leak_shift: u32,
    },
}

impl ArchDesc {
    /// Parse the manifest's tagged `arch` object (`{"kind": "mlp", ...}`).
    pub fn from_json(v: &Value) -> crate::Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or_default();
        let u = |key: &str| -> crate::Result<u64> {
            v.req(key)?
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("arch.{key} not an integer"))
        };
        let list = |key: &str| -> crate::Result<Vec<usize>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("arch.{key} not a list"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .map(|n| n as usize)
                        .ok_or_else(|| anyhow::anyhow!("arch.{key} element"))
                })
                .collect()
        };
        match kind {
            "mlp" => Ok(ArchDesc::Mlp {
                sizes: list("sizes")?,
                timesteps: u("timesteps")? as u32,
                leak_shift: u("leak_shift")? as u32,
            }),
            "convnet" => Ok(ArchDesc::Convnet {
                side: u("side")? as usize,
                channels: list("channels")?,
                classes: u("classes")? as usize,
                timesteps: u("timesteps")? as u32,
                leak_shift: u("leak_shift")? as u32,
            }),
            other => anyhow::bail!("unknown arch kind {other:?}"),
        }
    }

    /// Inference timesteps the network was trained for.
    pub fn timesteps(&self) -> u32 {
        match self {
            ArchDesc::Mlp { timesteps, .. } => *timesteps,
            ArchDesc::Convnet { timesteps, .. } => *timesteps,
        }
    }

    /// LIF leak shift shared by all layers.
    pub fn leak_shift(&self) -> u32 {
        match self {
            ArchDesc::Mlp { leak_shift, .. } => *leak_shift,
            ArchDesc::Convnet { leak_shift, .. } => *leak_shift,
        }
    }

    /// Encoder input size (pixels per sample).
    pub fn input_dim(&self) -> usize {
        match self {
            ArchDesc::Mlp { sizes, .. } => sizes[0],
            ArchDesc::Convnet { side, channels, .. } => side * side * channels[0],
        }
    }

    /// Output classes.
    pub fn classes(&self) -> usize {
        match self {
            ArchDesc::Mlp { sizes, .. } => *sizes.last().unwrap(),
            ArchDesc::Convnet { classes, .. } => *classes,
        }
    }

    /// Expected per-layer (k_in, n_out) shapes; used to validate LSPW files.
    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        match self {
            ArchDesc::Mlp { sizes, .. } => {
                sizes.windows(2).map(|w| (w[0], w[1])).collect()
            }
            ArchDesc::Convnet { side, channels, classes, .. } => {
                let fc_in = (side / 4) * (side / 4) * channels[2];
                vec![
                    (9 * channels[0], channels[1]),
                    (9 * channels[1], channels[2]),
                    (fc_in, *classes),
                ]
            }
        }
    }

    /// Total neurons (membrane words) — the V-scratchpad footprint.
    pub fn total_neurons(&self) -> usize {
        match self {
            ArchDesc::Mlp { sizes, .. } => sizes[1..].iter().sum(),
            ArchDesc::Convnet { side, channels, classes, .. } => {
                side * side * channels[1]
                    + (side / 2) * (side / 2) * channels[2]
                    + classes
            }
        }
    }

    /// Synaptic operations per timestep assuming dense activity
    /// (upper bound; the event-driven engine does less).
    pub fn synops_per_step(&self) -> u64 {
        self.layer_shapes()
            .iter()
            .zip(self.layer_positions())
            .map(|(&(k, n), pos)| (k * n * pos) as u64)
            .sum()
    }

    /// Spatial positions each layer's dense step runs at (1 for fc,
    /// H*W for conv layers mapped through im2col).
    pub fn layer_positions(&self) -> Vec<usize> {
        match self {
            ArchDesc::Mlp { sizes, .. } => vec![1; sizes.len() - 1],
            ArchDesc::Convnet { side, .. } => {
                vec![side * side, (side / 2) * (side / 2), 1]
            }
        }
    }
}

/// One loaded layer: packed weights + folded integer parameters.
#[derive(Debug, Clone)]
pub struct QuantNetLayer {
    /// Field width of the packed weights.
    pub precision: Precision,
    /// Input rows (fan-in).
    pub k_in: usize,
    /// Output neurons.
    pub n_out: usize,
    /// Packed words per weight row.
    pub n_words: usize,
    /// Dequantization scale (float domain).
    pub scale: f32,
    /// Folded integer firing threshold.
    pub theta: i32,
    /// Row-major `[k_in][n_words]` storage words.
    pub packed: Vec<u32>,
}

impl QuantNetLayer {
    /// Packed storage footprint in bits (what Fig. 4's x-axis measures).
    pub fn memory_bits(&self) -> usize {
        self.packed.len() * 32
    }
}

/// A complete quantized network ready for the engine or the simulator.
#[derive(Debug, Clone)]
pub struct QuantNetwork {
    /// Architecture topology.
    pub arch: ArchDesc,
    /// Per-layer packed weights, input to output order.
    pub layers: Vec<QuantNetLayer>,
    /// Pruned-network marker: set by the sparse (v2) LSPW loader and by
    /// `forge::prune_network`. When true the engine builds per-layer
    /// skip indices and routes through the sparse kernel walk; dense
    /// artifacts keep the exact `active_rows * n_words` word-traffic
    /// accounting, so sparsity is always an explicit property of the
    /// artifact, never inferred from zero-valued packed words.
    pub sparse_weights: bool,
}

impl QuantNetwork {
    /// Total packed weight footprint in bits.
    pub fn memory_bits(&self) -> usize {
        self.layers.iter().map(|l| l.memory_bits()).sum()
    }

    /// Uniform precision of the network (all artifacts are uniform today;
    /// layer-adaptive precision is the paper's future-work knob).
    pub fn precision(&self) -> Precision {
        self.layers[0].precision
    }

    /// Validate layer shapes against the architecture description.
    pub fn validate(&self) -> crate::Result<()> {
        let shapes = self.arch.layer_shapes();
        if shapes.len() != self.layers.len() {
            anyhow::bail!(
                "layer count mismatch: arch {} vs weights {}",
                shapes.len(),
                self.layers.len()
            );
        }
        for (i, (l, &(k, n))) in self.layers.iter().zip(&shapes).enumerate() {
            if l.k_in != k || l.n_out != n {
                anyhow::bail!(
                    "layer {i} shape mismatch: arch ({k},{n}) vs weights ({},{})",
                    l.k_in,
                    l.n_out
                );
            }
            let expect_words = n.div_ceil(l.precision.fields_per_word());
            if l.n_words != expect_words {
                anyhow::bail!("layer {i} word count mismatch");
            }
            if l.packed.len() != l.k_in * l.n_words {
                anyhow::bail!("layer {i} payload size mismatch");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp() -> ArchDesc {
        ArchDesc::Mlp { sizes: vec![256, 128, 64, 10], timesteps: 16, leak_shift: 2 }
    }

    fn conv() -> ArchDesc {
        ArchDesc::Convnet {
            side: 16,
            channels: vec![1, 8, 16],
            classes: 10,
            timesteps: 16,
            leak_shift: 2,
        }
    }

    #[test]
    fn mlp_shapes() {
        assert_eq!(mlp().layer_shapes(), vec![(256, 128), (128, 64), (64, 10)]);
        assert_eq!(mlp().input_dim(), 256);
        assert_eq!(mlp().classes(), 10);
        assert_eq!(mlp().total_neurons(), 202);
    }

    #[test]
    fn conv_shapes() {
        assert_eq!(conv().layer_shapes(), vec![(9, 8), (72, 16), (256, 10)]);
        assert_eq!(conv().input_dim(), 256);
        assert_eq!(conv().total_neurons(), 16 * 16 * 8 + 8 * 8 * 16 + 10);
        assert_eq!(conv().layer_positions(), vec![256, 64, 1]);
    }

    #[test]
    fn synops() {
        // mlp: 256*128 + 128*64 + 64*10 = 41600 per step
        assert_eq!(mlp().synops_per_step(), 41600);
    }

    #[test]
    fn arch_json_roundtrip() {
        let j = r#"{"kind":"mlp","sizes":[256,128,64,10],"timesteps":16,"leak_shift":2}"#;
        let a = ArchDesc::from_json(&crate::util::json::parse(j).unwrap()).unwrap();
        assert_eq!(a, mlp());
        let j2 = r#"{"kind":"convnet","side":16,"channels":[1,8,16],"classes":10,"timesteps":16,"leak_shift":2}"#;
        let a2 = ArchDesc::from_json(&crate::util::json::parse(j2).unwrap()).unwrap();
        assert_eq!(a2, conv());
    }

    #[test]
    fn arch_json_rejects_bad_kind() {
        let j = r#"{"kind":"resnet","timesteps":16,"leak_shift":2}"#;
        assert!(ArchDesc::from_json(&crate::util::json::parse(j).unwrap()).is_err());
    }
}
