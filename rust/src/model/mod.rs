//! Quantized SNN models: artifact loaders + the integer inference engine.
//!
//! - [`io`] — binary readers for the python-exported artifacts:
//!   LSPW packed weights, LSPD test datasets, and the JSON manifest.
//! - [`network`] — the architecture description (MLP / ConvNet) shared
//!   with `python/compile/snn.py`.
//! - [`engine`] — bit-accurate integer inference over [`crate::nce`];
//!   produces spike counts identical to the pallas/PJRT path (asserted by
//!   `rust/tests/integration.rs`).

pub mod engine;
pub mod io;
pub mod network;

pub use engine::{MembraneState, ResetPolicy, SnnEngine};
pub use io::{
    load_dataset, load_manifest, load_stream, load_weights, parse_stream, Dataset,
    Manifest, StreamData,
};
pub use network::{ArchDesc, QuantNetwork, QuantNetLayer};
