//! RV32I interpreter core (1 instruction / cycle, like pico-rv32's
//! non-pipelined mode for the control-path subset we use).

use super::bus::Bus;

/// Execution traps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trap {
    /// `ebreak` — clean program completion in our convention.
    Break,
    /// `ecall` — host call (register a7 selects the function).
    Ecall,
    /// Undecodable instruction word at `pc`.
    IllegalInstruction(u32),
    /// Jump/branch target not 4-byte aligned.
    MisalignedPc(u32),
}

/// The CPU state.
pub struct Cpu {
    /// x0..x31 (x0 reads as zero by decode convention).
    pub regs: [u32; 32],
    /// Program counter (byte address).
    pub pc: u32,
    /// Retired instruction count (== cycles at CPI 1).
    pub cycles: u64,
}

impl Default for Cpu {
    fn default() -> Self {
        Self::new()
    }
}

impl Cpu {
    /// CPU at pc 0 with zeroed registers.
    pub fn new() -> Self {
        Self { regs: [0; 32], pc: 0, cycles: 0 }
    }

    fn x(&self, r: u32) -> u32 {
        self.regs[r as usize]
    }

    fn set_x(&mut self, r: u32, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    /// Execute one instruction; `Ok(())` or a trap.
    pub fn step(&mut self, bus: &mut Bus) -> Result<(), Trap> {
        if self.pc % 4 != 0 {
            return Err(Trap::MisalignedPc(self.pc));
        }
        let inst = bus.read_u32(self.pc);
        let opcode = inst & 0x7F;
        let rd = (inst >> 7) & 0x1F;
        let funct3 = (inst >> 12) & 0x7;
        let rs1 = (inst >> 15) & 0x1F;
        let rs2 = (inst >> 20) & 0x1F;
        let funct7 = inst >> 25;

        let imm_i = (inst as i32) >> 20;
        let imm_s = (((inst & 0xFE00_0000) as i32) >> 20) | (((inst >> 7) & 0x1F) as i32);
        let imm_b = ((((inst >> 31) & 1) << 12)
            | (((inst >> 7) & 1) << 11)
            | (((inst >> 25) & 0x3F) << 5)
            | (((inst >> 8) & 0xF) << 1)) as i32;
        let imm_b = (imm_b << 19) >> 19; // sign-extend 13-bit
        let imm_u = (inst & 0xFFFF_F000) as i32;
        let imm_j = ((((inst >> 31) & 1) << 20)
            | (((inst >> 12) & 0xFF) << 12)
            | (((inst >> 20) & 1) << 11)
            | (((inst >> 21) & 0x3FF) << 1)) as i32;
        let imm_j = (imm_j << 11) >> 11; // sign-extend 21-bit

        let mut next_pc = self.pc.wrapping_add(4);
        match opcode {
            0x37 => self.set_x(rd, imm_u as u32), // lui
            0x17 => self.set_x(rd, self.pc.wrapping_add(imm_u as u32)), // auipc
            0x6F => {
                // jal
                self.set_x(rd, next_pc);
                next_pc = self.pc.wrapping_add(imm_j as u32);
            }
            0x67 => {
                // jalr
                let t = next_pc;
                next_pc = self.x(rs1).wrapping_add(imm_i as u32) & !1;
                self.set_x(rd, t);
            }
            0x63 => {
                // branches
                let (a, b) = (self.x(rs1), self.x(rs2));
                let taken = match funct3 {
                    0 => a == b,
                    1 => a != b,
                    4 => (a as i32) < (b as i32),
                    5 => (a as i32) >= (b as i32),
                    6 => a < b,
                    7 => a >= b,
                    _ => return Err(Trap::IllegalInstruction(inst)),
                };
                if taken {
                    next_pc = self.pc.wrapping_add(imm_b as u32);
                }
            }
            0x03 => {
                // loads
                let addr = self.x(rs1).wrapping_add(imm_i as u32);
                let v = match funct3 {
                    0 => bus.read_u8(addr) as i8 as i32 as u32, // lb
                    1 => bus.read_u16(addr) as i16 as i32 as u32, // lh
                    2 => bus.read_u32(addr),                    // lw
                    4 => bus.read_u8(addr) as u32,              // lbu
                    5 => bus.read_u16(addr) as u32,             // lhu
                    _ => return Err(Trap::IllegalInstruction(inst)),
                };
                self.set_x(rd, v);
            }
            0x23 => {
                // stores
                let addr = self.x(rs1).wrapping_add(imm_s as u32);
                match funct3 {
                    0 => bus.write_u8(addr, self.x(rs2) as u8),
                    1 => bus.write_u16(addr, self.x(rs2) as u16),
                    2 => bus.write_u32(addr, self.x(rs2)),
                    _ => return Err(Trap::IllegalInstruction(inst)),
                }
            }
            0x13 => {
                // op-imm
                let a = self.x(rs1);
                let b = imm_i as u32;
                let shamt = rs2;
                let v = match funct3 {
                    0 => a.wrapping_add(b),
                    1 => a << shamt,
                    2 => ((a as i32) < (b as i32)) as u32,
                    3 => (a < b) as u32,
                    4 => a ^ b,
                    5 => {
                        if funct7 & 0x20 != 0 {
                            ((a as i32) >> shamt) as u32
                        } else {
                            a >> shamt
                        }
                    }
                    6 => a | b,
                    7 => a & b,
                    _ => return Err(Trap::IllegalInstruction(inst)),
                };
                self.set_x(rd, v);
            }
            0x33 => {
                // op
                let (a, b) = (self.x(rs1), self.x(rs2));
                let v = match (funct3, funct7) {
                    (0, 0x00) => a.wrapping_add(b),
                    (0, 0x20) => a.wrapping_sub(b),
                    (1, 0x00) => a << (b & 31),
                    (2, 0x00) => ((a as i32) < (b as i32)) as u32,
                    (3, 0x00) => (a < b) as u32,
                    (4, 0x00) => a ^ b,
                    (5, 0x00) => a >> (b & 31),
                    (5, 0x20) => ((a as i32) >> (b & 31)) as u32,
                    (6, 0x00) => a | b,
                    (7, 0x00) => a & b,
                    _ => return Err(Trap::IllegalInstruction(inst)),
                };
                self.set_x(rd, v);
            }
            0x73 => {
                self.cycles += 1;
                self.pc = next_pc;
                return Err(if imm_i == 1 { Trap::Break } else { Trap::Ecall });
            }
            _ => return Err(Trap::IllegalInstruction(inst)),
        }
        self.pc = next_pc;
        self.cycles += 1;
        Ok(())
    }

    /// Run until `ebreak` (or any trap / the step limit). Returns cycles.
    pub fn run(&mut self, bus: &mut Bus, max_steps: u64) -> Result<u64, Trap> {
        let start = self.cycles;
        for _ in 0..max_steps {
            match self.step(bus) {
                Ok(()) => {}
                Err(Trap::Break) => return Ok(self.cycles - start),
                Err(t) => return Err(t),
            }
        }
        Err(Trap::IllegalInstruction(0xFFFF_FFFF)) // step-limit runaway
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::riscv::asm::Assembler;
    use crate::riscv::bus::{ArrayDevice, Ram};

    fn make_bus(prog: &[u8]) -> Bus {
        let mut ram = Ram::new(64 * 1024);
        ram.load(0, prog);
        Bus::new(ram, ArrayDevice::new(vec![1000], vec![5]))
    }

    #[test]
    fn arithmetic_program() {
        // x1 = 10; x2 = 32; x3 = x1 + x2; x4 = x3 - x1; mem[64] = x4
        let mut a = Assembler::new();
        a.addi(1, 0, 10);
        a.addi(2, 0, 32);
        a.add(3, 1, 2);
        a.sub(4, 3, 1);
        a.sw(0, 4, 64);
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 100).unwrap();
        assert_eq!(cpu.regs[3], 42);
        assert_eq!(bus.ram.read_u32(64), 32);
    }

    #[test]
    fn branch_loop_sums() {
        // sum 1..=5 via a loop
        let mut a = Assembler::new();
        a.addi(1, 0, 5); // counter
        a.addi(2, 0, 0); // acc
        let top = a.here();
        a.add(2, 2, 1);
        a.addi(1, 1, -1);
        a.bne(1, 0, top);
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 100).unwrap();
        assert_eq!(cpu.regs[2], 15);
    }

    #[test]
    fn shifts_and_logic() {
        let mut a = Assembler::new();
        a.addi(1, 0, -8); // 0xFFFFFFF8
        a.srai(2, 1, 2); // -2
        a.srli(3, 1, 28); // 0xF
        a.andi(4, 1, 0xF); // 8
        a.xori(5, 3, 0x5); // 0xA
        a.slli(6, 3, 4); // 0xF0
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 100).unwrap();
        assert_eq!(cpu.regs[2] as i32, -2);
        assert_eq!(cpu.regs[3], 0xF);
        assert_eq!(cpu.regs[4], 8);
        assert_eq!(cpu.regs[5], 0xA);
        assert_eq!(cpu.regs[6], 0xF0);
    }

    #[test]
    fn byte_halfword_memory() {
        let mut a = Assembler::new();
        a.addi(1, 0, -1); // 0xFFFFFFFF
        a.sb(0, 1, 100);
        a.lb(2, 0, 100); // -1 sign-extended
        a.lbu(3, 0, 100); // 255
        a.addi(4, 0, 0x7FF);
        a.sh(0, 4, 104);
        a.lh(5, 0, 104);
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 100).unwrap();
        assert_eq!(cpu.regs[2], 0xFFFF_FFFF);
        assert_eq!(cpu.regs[3], 255);
        assert_eq!(cpu.regs[5], 0x7FF);
    }

    #[test]
    fn jal_and_jalr_call_return() {
        let mut a = Assembler::new();
        a.addi(1, 0, 7);
        let call = a.jal_placeholder(5); // x5 = link
        a.ebreak();
        // function: double x1 and return
        let fn_addr = a.here();
        a.add(1, 1, 1);
        a.jalr(0, 5, 0);
        a.patch_jal(call, fn_addr);
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 100).unwrap();
        assert_eq!(cpu.regs[1], 14);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let mut a = Assembler::new();
        a.addi(0, 0, 99);
        a.add(1, 0, 0);
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        cpu.run(&mut bus, 10).unwrap();
        assert_eq!(cpu.regs[0], 0);
        assert_eq!(cpu.regs[1], 0);
    }

    #[test]
    fn illegal_instruction_traps() {
        let mut bus = make_bus(&[0xFF, 0xFF, 0xFF, 0xFF]);
        let mut cpu = Cpu::new();
        assert!(matches!(
            cpu.step(&mut bus),
            Err(Trap::IllegalInstruction(_))
        ));
    }

    #[test]
    fn runaway_program_hits_step_limit() {
        // infinite loop: jal x0, 0
        let mut a = Assembler::new();
        let top = a.here();
        a.jal_to(0, top);
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        assert!(cpu.run(&mut bus, 50).is_err());
    }

    #[test]
    fn mmio_poll_loop() {
        use crate::riscv::bus::{array_regs, MMIO_BASE};
        // select layer 0, start, poll BUSY until clear, read cycles
        let mut a = Assembler::new();
        a.lui(1, MMIO_BASE >> 12);
        a.sw(1, 0, array_regs::LAYER_SEL as i32);
        a.addi(2, 0, 16);
        a.sw(1, 2, array_regs::START as i32);
        let poll = a.here();
        a.lw(3, 1, array_regs::BUSY as i32);
        a.bne(3, 0, poll);
        a.lw(4, 1, array_regs::CYCLES_LO as i32);
        a.ebreak();
        let mut bus = make_bus(&a.finish());
        let mut cpu = Cpu::new();
        let cycles = cpu.run(&mut bus, 1000).unwrap();
        assert_eq!(cpu.regs[4], 1000); // ArrayDevice layer_cycles[0]
        assert_eq!(bus.array.starts, 1);
        assert!(cycles > 5); // setup + >=1 poll iterations
    }
}
