//! Tiny RV32I assembler for the controller's firmware.
//!
//! Emits little-endian machine code consumed by [`super::cpu::Cpu`]; the
//! control programs (layer orchestration loops) are built with it in
//! `coordinator::firmware` and the tests. Only the encodings the control
//! path needs — this is firmware tooling, not a general assembler.

/// Builds a program as a growing word buffer with absolute byte labels.
#[derive(Debug, Default, Clone)]
pub struct Assembler {
    words: Vec<u32>,
}

fn enc_r(funct7: u32, rs2: u32, rs1: u32, funct3: u32, rd: u32, opcode: u32) -> u32 {
    (funct7 << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opcode
}

fn enc_i(imm: i32, rs1: u32, funct3: u32, rd: u32, opcode: u32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "I-imm out of range: {imm}");
    ((imm as u32 & 0xFFF) << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opcode
}

fn enc_s(imm: i32, rs2: u32, rs1: u32, funct3: u32, opcode: u32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "S-imm out of range: {imm}");
    let imm = imm as u32 & 0xFFF;
    ((imm >> 5) << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | ((imm & 0x1F) << 7) | opcode
}

fn enc_b(offset: i32, rs2: u32, rs1: u32, funct3: u32) -> u32 {
    assert!(offset % 2 == 0 && (-4096..=4094).contains(&offset));
    let imm = offset as u32;
    (((imm >> 12) & 1) << 31)
        | (((imm >> 5) & 0x3F) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (funct3 << 12)
        | (((imm >> 1) & 0xF) << 8)
        | (((imm >> 11) & 1) << 7)
        | 0x63
}

fn enc_j(offset: i32, rd: u32) -> u32 {
    assert!(offset % 2 == 0 && (-(1 << 20)..(1 << 20)).contains(&offset));
    let imm = offset as u32;
    (((imm >> 20) & 1) << 31)
        | (((imm >> 1) & 0x3FF) << 21)
        | (((imm >> 11) & 1) << 20)
        | (((imm >> 12) & 0xFF) << 12)
        | (rd << 7)
        | 0x6F
}

impl Assembler {
    /// Empty program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current byte address (next instruction's location).
    pub fn here(&self) -> u32 {
        (self.words.len() * 4) as u32
    }

    fn emit(&mut self, w: u32) -> u32 {
        let at = self.here();
        self.words.push(w);
        at
    }

    /// The assembled program as little-endian bytes.
    pub fn finish(self) -> Vec<u8> {
        self.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    // --- op-imm / op ---
    /// Emit `addi`.
    pub fn addi(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 0, rd, 0x13))
    }
    /// Emit `andi`.
    pub fn andi(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 7, rd, 0x13))
    }
    /// Emit `ori`.
    pub fn ori(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 6, rd, 0x13))
    }
    /// Emit `xori`.
    pub fn xori(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 4, rd, 0x13))
    }
    /// Emit `slti`.
    pub fn slti(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 2, rd, 0x13))
    }
    /// Emit `slli`.
    pub fn slli(&mut self, rd: u32, rs1: u32, shamt: u32) -> u32 {
        self.emit(enc_r(0, shamt, rs1, 1, rd, 0x13))
    }
    /// Emit `srli`.
    pub fn srli(&mut self, rd: u32, rs1: u32, shamt: u32) -> u32 {
        self.emit(enc_r(0, shamt, rs1, 5, rd, 0x13))
    }
    /// Emit `srai`.
    pub fn srai(&mut self, rd: u32, rs1: u32, shamt: u32) -> u32 {
        self.emit(enc_r(0x20, shamt, rs1, 5, rd, 0x13))
    }
    /// Emit `add`.
    pub fn add(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0, rs2, rs1, 0, rd, 0x33))
    }
    /// Emit `sub`.
    pub fn sub(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0x20, rs2, rs1, 0, rd, 0x33))
    }
    /// Emit `and`.
    pub fn and(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0, rs2, rs1, 7, rd, 0x33))
    }
    /// Emit `or`.
    pub fn or(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0, rs2, rs1, 6, rd, 0x33))
    }
    /// Emit `xor`.
    pub fn xor(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0, rs2, rs1, 4, rd, 0x33))
    }
    /// Emit `sll`.
    pub fn sll(&mut self, rd: u32, rs1: u32, rs2: u32) -> u32 {
        self.emit(enc_r(0, rs2, rs1, 1, rd, 0x33))
    }

    // --- upper immediates ---
    /// Emit `lui`.
    pub fn lui(&mut self, rd: u32, imm20: u32) -> u32 {
        self.emit((imm20 << 12) | (rd << 7) | 0x37)
    }
    /// Emit `auipc`.
    pub fn auipc(&mut self, rd: u32, imm20: u32) -> u32 {
        self.emit((imm20 << 12) | (rd << 7) | 0x17)
    }

    // --- memory ---
    /// Emit `lw`.
    pub fn lw(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 2, rd, 0x03))
    }
    /// Emit `lb`.
    pub fn lb(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 0, rd, 0x03))
    }
    /// Emit `lbu`.
    pub fn lbu(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 4, rd, 0x03))
    }
    /// Emit `lh`.
    pub fn lh(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 1, rd, 0x03))
    }
    /// Emit `sw`.
    pub fn sw(&mut self, rs1: u32, rs2: u32, imm: i32) -> u32 {
        self.emit(enc_s(imm, rs2, rs1, 2, 0x23))
    }
    /// Emit `sb`.
    pub fn sb(&mut self, rs1: u32, rs2: u32, imm: i32) -> u32 {
        self.emit(enc_s(imm, rs2, rs1, 0, 0x23))
    }
    /// Emit `sh`.
    pub fn sh(&mut self, rs1: u32, rs2: u32, imm: i32) -> u32 {
        self.emit(enc_s(imm, rs2, rs1, 1, 0x23))
    }

    // --- control flow (targets are absolute byte addresses) ---
    /// Emit `beq`.
    pub fn beq(&mut self, rs1: u32, rs2: u32, target: u32) -> u32 {
        let off = target as i32 - self.here() as i32;
        self.emit(enc_b(off, rs2, rs1, 0))
    }
    /// Emit `bne`.
    pub fn bne(&mut self, rs1: u32, rs2: u32, target: u32) -> u32 {
        let off = target as i32 - self.here() as i32;
        self.emit(enc_b(off, rs2, rs1, 1))
    }
    /// Emit `blt`.
    pub fn blt(&mut self, rs1: u32, rs2: u32, target: u32) -> u32 {
        let off = target as i32 - self.here() as i32;
        self.emit(enc_b(off, rs2, rs1, 4))
    }
    /// Emit `bge`.
    pub fn bge(&mut self, rs1: u32, rs2: u32, target: u32) -> u32 {
        let off = target as i32 - self.here() as i32;
        self.emit(enc_b(off, rs2, rs1, 5))
    }
    /// Emit `jal`.
    pub fn jal_to(&mut self, rd: u32, target: u32) -> u32 {
        let off = target as i32 - self.here() as i32;
        self.emit(enc_j(off, rd))
    }
    /// Emit `jalr`.
    pub fn jalr(&mut self, rd: u32, rs1: u32, imm: i32) -> u32 {
        self.emit(enc_i(imm, rs1, 0, rd, 0x67))
    }

    /// Emit a `jal` whose target is patched later (forward reference).
    pub fn jal_placeholder(&mut self, rd: u32) -> u32 {
        self.emit(enc_j(0, rd))
    }

    /// Patch a placeholder `jal` at byte address `at` to jump to `target`.
    pub fn patch_jal(&mut self, at: u32, target: u32) {
        let rd = (self.words[at as usize / 4] >> 7) & 0x1F;
        self.words[at as usize / 4] = enc_j(target as i32 - at as i32, rd);
    }

    // --- system ---
    /// Emit `ebreak`.
    pub fn ebreak(&mut self) -> u32 {
        self.emit(0x0010_0073)
    }
    /// Emit `ecall`.
    pub fn ecall(&mut self) -> u32 {
        self.emit(0x0000_0073)
    }

    /// Load a full 32-bit constant (lui + addi pair, sign-fixup included).
    pub fn li32(&mut self, rd: u32, value: u32) {
        let lo = (value & 0xFFF) as i32;
        let lo = (lo << 20) >> 20; // sign-extend 12-bit
        let hi = value.wrapping_sub(lo as u32) >> 12;
        self.lui(rd, hi);
        if lo != 0 {
            self.addi(rd, rd, lo);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_encodings() {
        // cross-checked against riscv-tests reference encodings
        let mut a = Assembler::new();
        a.addi(1, 0, 10);
        a.add(3, 1, 2);
        a.sub(4, 3, 1);
        let code = a.finish();
        let w = |i: usize| u32::from_le_bytes(code[i * 4..i * 4 + 4].try_into().unwrap());
        assert_eq!(w(0), 0x00A0_0093); // addi x1, x0, 10
        assert_eq!(w(1), 0x0020_81B3); // add x3, x1, x2
        assert_eq!(w(2), 0x4011_8233); // sub x4, x3, x1
    }

    #[test]
    fn store_load_encoding() {
        let mut a = Assembler::new();
        a.sw(0, 4, 64);
        a.lw(4, 0, 64);
        let code = a.finish();
        let w = |i: usize| u32::from_le_bytes(code[i * 4..i * 4 + 4].try_into().unwrap());
        assert_eq!(w(0), 0x0440_2023); // sw x4, 64(x0)
        assert_eq!(w(1), 0x0400_2203); // lw x4, 64(x0)
    }

    #[test]
    fn branch_offset_negative() {
        let mut a = Assembler::new();
        a.addi(1, 0, 1); // 0x0
        let top = a.here(); // 0x4
        a.addi(1, 1, 1); // 0x4
        a.bne(1, 0, top); // 0x8, offset -4
        let code = a.finish();
        let w = u32::from_le_bytes(code[8..12].try_into().unwrap());
        assert_eq!(w, 0xFE00_9EE3); // bne x1, x0, -4
    }

    #[test]
    fn li32_roundtrip() {
        use crate::riscv::bus::{ArrayDevice, Bus, Ram};
        use crate::riscv::cpu::Cpu;
        for value in [0u32, 1, 0xFFF, 0x1000, 0x4000_0000, 0xDEAD_BEEF, u32::MAX] {
            let mut a = Assembler::new();
            a.li32(5, value);
            a.ebreak();
            let mut ram = Ram::new(4096);
            ram.load(0, &a.finish());
            let mut bus = Bus::new(ram, ArrayDevice::new(vec![], vec![]));
            let mut cpu = Cpu::new();
            cpu.run(&mut bus, 10).unwrap();
            assert_eq!(cpu.regs[5], value, "li32({value:#x})");
        }
    }

    #[test]
    #[should_panic(expected = "I-imm out of range")]
    fn rejects_oversized_immediate() {
        Assembler::new().addi(1, 0, 5000);
    }
}
