//! pico-rv32-class RISC-V controller (Fig. 1, left block).
//!
//! A compact RV32I interpreter standing in for the pico-rv32 soft core
//! the paper integrates: it executes real control programs (assembled by
//! [`asm`]) that program layer descriptors over MMIO, start the NCE array
//! and poll for completion. The cycle cost of this orchestration is what
//! `array::sim` charges as `riscv_per_layer`; `examples/riscv_demo.rs`
//! co-simulates the controller against the array device to validate it.
//!
//! Subset: full RV32I base integer ISA (no CSRs, no fences, no
//! compressed) — the subset the control path actually uses.

pub mod asm;
pub mod bus;
pub mod cpu;

pub use asm::Assembler;
pub use bus::{ArrayDevice, Bus, Ram};
pub use cpu::{Cpu, Trap};
