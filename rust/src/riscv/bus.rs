//! Memory bus: RAM + MMIO devices (the NCE array control registers).

/// Word-addressable memory-mapped device.
pub trait Device {
    /// 32-bit read at a device-relative byte offset.
    fn read(&mut self, offset: u32) -> u32;
    /// 32-bit write at a device-relative byte offset.
    fn write(&mut self, offset: u32, value: u32);
}

/// Plain RAM device.
#[derive(Debug, Clone)]
pub struct Ram {
    bytes: Vec<u8>,
}

impl Ram {
    /// Zero-filled RAM of `size` bytes.
    pub fn new(size: usize) -> Self {
        Self { bytes: vec![0; size] }
    }

    /// Copy `data` into RAM at `addr` (program/firmware load).
    pub fn load(&mut self, addr: usize, data: &[u8]) {
        self.bytes[addr..addr + data.len()].copy_from_slice(data);
    }

    /// Read one byte.
    pub fn read_u8(&self, addr: u32) -> u8 {
        self.bytes[addr as usize]
    }

    /// Write one byte.
    pub fn write_u8(&mut self, addr: u32, v: u8) {
        self.bytes[addr as usize] = v;
    }

    /// Read a little-endian word.
    pub fn read_u32(&self, addr: u32) -> u32 {
        let a = addr as usize;
        u32::from_le_bytes(self.bytes[a..a + 4].try_into().unwrap())
    }

    /// Write a little-endian word.
    pub fn write_u32(&mut self, addr: u32, v: u32) {
        let a = addr as usize;
        self.bytes[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// RAM size in bytes.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    /// True for a zero-size RAM.
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// MMIO register map of the NCE array controller (device-relative).
pub mod array_regs {
    /// W: layer descriptor index to configure.
    pub const LAYER_SEL: u32 = 0x00;
    /// W: start the selected layer (value = timestep count).
    pub const START: u32 = 0x04;
    /// R: busy flag (1 while the array runs).
    pub const BUSY: u32 = 0x08;
    /// R: cycles consumed by the last layer run.
    pub const CYCLES_LO: u32 = 0x0C;
    /// R: high half of the cycle counter.
    pub const CYCLES_HI: u32 = 0x10;
    /// R: spikes emitted by the last layer run.
    pub const SPIKES: u32 = 0x14;
}

/// The NCE-array MMIO device used in co-simulation: completing a layer
/// takes a programmed number of polls (modelling the real busy window).
#[derive(Debug, Clone)]
pub struct ArrayDevice {
    /// Cycle cost of each layer (set by the testbench / simulator).
    pub layer_cycles: Vec<u64>,
    /// Spike count each layer reports (set by the testbench).
    pub layer_spikes: Vec<u32>,
    selected: usize,
    busy_polls_left: u32,
    /// busy-polls a layer stays busy per 1000 cycles of layer work.
    polls_per_kcycle: u32,
    last_cycles: u64,
    last_spikes: u32,
    /// START writes observed (firmware-behavior assertions).
    pub starts: u32,
}

impl ArrayDevice {
    /// Device preloaded with per-layer cycle/spike results.
    pub fn new(layer_cycles: Vec<u64>, layer_spikes: Vec<u32>) -> Self {
        Self {
            layer_cycles,
            layer_spikes,
            selected: 0,
            busy_polls_left: 0,
            polls_per_kcycle: 2,
            last_cycles: 0,
            last_spikes: 0,
            starts: 0,
        }
    }
}

impl Device for ArrayDevice {
    fn read(&mut self, offset: u32) -> u32 {
        match offset {
            array_regs::BUSY => {
                if self.busy_polls_left > 0 {
                    self.busy_polls_left -= 1;
                    1
                } else {
                    0
                }
            }
            array_regs::CYCLES_LO => self.last_cycles as u32,
            array_regs::CYCLES_HI => (self.last_cycles >> 32) as u32,
            array_regs::SPIKES => self.last_spikes,
            _ => 0,
        }
    }

    fn write(&mut self, offset: u32, value: u32) {
        match offset {
            array_regs::LAYER_SEL => self.selected = value as usize,
            array_regs::START => {
                let _timesteps = value; // informational; cycle cost is per-layer
                let cycles = self.layer_cycles.get(self.selected).copied().unwrap_or(0);
                self.last_cycles = cycles;
                self.last_spikes =
                    self.layer_spikes.get(self.selected).copied().unwrap_or(0);
                self.busy_polls_left =
                    ((cycles / 1000) as u32 * self.polls_per_kcycle).max(1);
                self.starts += 1;
            }
            _ => {}
        }
    }
}

/// The system bus: RAM at 0x0000_0000, array MMIO at 0x4000_0000.
pub struct Bus {
    /// RAM at address 0.
    pub ram: Ram,
    /// NCE-array MMIO device at [`MMIO_BASE`].
    pub array: ArrayDevice,
}

/// Base address of the array's MMIO window.
pub const MMIO_BASE: u32 = 0x4000_0000;

impl Bus {
    /// Bus over the two devices.
    pub fn new(ram: Ram, array: ArrayDevice) -> Self {
        Self { ram, array }
    }

    /// Word read, routed by address.
    pub fn read_u32(&mut self, addr: u32) -> u32 {
        if addr >= MMIO_BASE {
            self.array.read(addr - MMIO_BASE)
        } else {
            self.ram.read_u32(addr)
        }
    }

    /// Word write, routed by address.
    pub fn write_u32(&mut self, addr: u32, v: u32) {
        if addr >= MMIO_BASE {
            self.array.write(addr - MMIO_BASE, v);
        } else {
            self.ram.write_u32(addr, v);
        }
    }

    /// Byte read, routed by address.
    pub fn read_u8(&mut self, addr: u32) -> u8 {
        if addr >= MMIO_BASE {
            (self.array.read(addr - MMIO_BASE) & 0xFF) as u8
        } else {
            self.ram.read_u8(addr)
        }
    }

    /// Byte write, routed by address.
    pub fn write_u8(&mut self, addr: u32, v: u8) {
        if addr >= MMIO_BASE {
            self.array.write(addr - MMIO_BASE, v as u32);
        } else {
            self.ram.write_u8(addr, v);
        }
    }

    /// Halfword read (two byte reads, little-endian).
    pub fn read_u16(&mut self, addr: u32) -> u16 {
        (self.read_u8(addr) as u16) | ((self.read_u8(addr + 1) as u16) << 8)
    }

    /// Halfword write (two byte writes, little-endian).
    pub fn write_u16(&mut self, addr: u32, v: u16) {
        self.write_u8(addr, (v & 0xFF) as u8);
        self.write_u8(addr + 1, (v >> 8) as u8);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ram_rw() {
        let mut r = Ram::new(64);
        r.write_u32(8, 0xDEADBEEF);
        assert_eq!(r.read_u32(8), 0xDEADBEEF);
        assert_eq!(r.read_u8(8), 0xEF); // little-endian
        assert_eq!(r.read_u8(11), 0xDE);
    }

    #[test]
    fn array_device_protocol() {
        let mut d = ArrayDevice::new(vec![5000, 2000], vec![42, 7]);
        d.write(array_regs::LAYER_SEL, 1);
        d.write(array_regs::START, 16);
        // busy for a few polls, then done
        let mut polls = 0;
        while d.read(array_regs::BUSY) == 1 {
            polls += 1;
            assert!(polls < 100);
        }
        assert!(polls >= 1);
        assert_eq!(d.read(array_regs::CYCLES_LO), 2000);
        assert_eq!(d.read(array_regs::SPIKES), 7);
        assert_eq!(d.starts, 1);
    }

    #[test]
    fn bus_routes_mmio() {
        let mut bus = Bus::new(Ram::new(64), ArrayDevice::new(vec![100], vec![1]));
        bus.write_u32(0, 7);
        assert_eq!(bus.read_u32(0), 7);
        bus.write_u32(MMIO_BASE + array_regs::LAYER_SEL, 0);
        bus.write_u32(MMIO_BASE + array_regs::START, 1);
        assert_eq!(bus.array.starts, 1);
    }
}
