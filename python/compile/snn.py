"""Training-time (FP32) spiking network with surrogate gradients.

This is the *author path* of the flow in Fig. 3: BPTT training with a
triangular surrogate around the firing threshold. The float dynamics are
written so that quantization maps them 1:1 onto the integer contract:

    float:   V' = V - V * 2^-k + I ;  spike = V' >= theta ; V'' = V' - theta
    integer: V' = V - (V >> k) + I ;  spike = V' >= theta_int ; ...

i.e. the decay is exactly ``1 - 2^-k`` (a shift in hardware) and reset is
by subtraction, so post-training quantization only rescales, never changes
the dynamical form.

No optax in this environment — a compact Adam lives here too.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels.ref import encode_step_ref

THETA_FP = 1.0


@dataclasses.dataclass(frozen=True)
class MlpArch:
    """Fully-connected spiking architecture (sizes include input/output)."""

    sizes: tuple[int, ...] = (256, 128, 64, 10)
    timesteps: int = 16
    leak_shift: int = 2

    @property
    def name(self) -> str:
        return "mlp"


@dataclasses.dataclass(frozen=True)
class ConvArch:
    """Spiking ConvNet: conv3x3 -> pool -> conv3x3 -> pool -> fc.

    Convolutions are expressed as im2col patches @ W so that *every* layer
    is the same dense LIF step the NCE executes (the paper's 2D-array
    dataflow maps conv onto the same engine).
    """

    side: int = 16
    channels: tuple[int, ...] = (1, 16, 32)
    classes: int = 10
    timesteps: int = 16
    leak_shift: int = 2

    @property
    def name(self) -> str:
        return "convnet"

    @property
    def fc_in(self) -> int:
        # two 2x2 max-pools: side/4 x side/4 x channels[-1]
        s = self.side // 4
        return s * s * self.channels[-1]


Arch = MlpArch | ConvArch


def init_params(arch: Arch, seed: int = 0) -> list[jnp.ndarray]:
    """He-style init; weights only (LIF layers have no bias — spikes carry
    unit current, matching the multiplier-less accumulate datapath)."""
    key = jax.random.PRNGKey(seed)
    params: list[jnp.ndarray] = []
    if isinstance(arch, MlpArch):
        dims = list(zip(arch.sizes[:-1], arch.sizes[1:]))
    else:
        dims = [
            (9 * arch.channels[0], arch.channels[1]),
            (9 * arch.channels[1], arch.channels[2]),
            (arch.fc_in, arch.classes),
        ]
    for k_in, k_out in dims:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (k_in, k_out), jnp.float32)
        params.append(w * jnp.sqrt(2.0 / k_in) * 2.5)
    return params


@jax.custom_jvp
def spike_fn(v: jnp.ndarray) -> jnp.ndarray:
    """Heaviside(v - theta) with a triangular surrogate derivative."""
    return (v >= THETA_FP).astype(jnp.float32)


@spike_fn.defjvp
def _spike_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    out = (v >= THETA_FP).astype(jnp.float32)
    grad = jnp.maximum(0.0, 1.0 - jnp.abs(v - THETA_FP) / THETA_FP)
    return out, grad * dv


def _lif_float(i_syn, v, leak_shift):
    v_new = v - v * (2.0**-leak_shift) + i_syn
    s = spike_fn(v_new)
    return s, v_new - s * THETA_FP


def _patches(x_img: jnp.ndarray, ch: int, side: int) -> jnp.ndarray:
    """im2col: [B, side, side, ch] -> [B*side*side, 9*ch] (SAME, 3x3)."""
    b = x_img.shape[0]
    x_nchw = jnp.transpose(x_img, (0, 3, 1, 2))
    p = lax.conv_general_dilated_patches(
        x_nchw, (3, 3), (1, 1), "SAME"
    )  # [B, ch*9, side, side]
    p = jnp.transpose(p, (0, 2, 3, 1))  # [B, side, side, ch*9]
    return p.reshape(b * side * side, ch * 9)


def _maxpool2(s_img: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool on binary spikes == OR; [B,H,W,C] -> [B,H/2,W/2,C]."""
    b, h, w, c = s_img.shape
    s = s_img.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(jnp.max(s, axis=4), axis=2)


def encode_all(x: jnp.ndarray, timesteps: int) -> jnp.ndarray:
    """Deterministic rate code for all timesteps: [T, B, K] float {0,1}."""
    x_u8 = jnp.clip(jnp.round(x * 255.0), 0, 255).astype(jnp.int32)
    return jnp.stack(
        [encode_step_ref(x_u8, t).astype(jnp.float32) for t in range(timesteps)]
    )


def forward_float(
    params: Sequence[jnp.ndarray], arch: Arch, x: jnp.ndarray
) -> jnp.ndarray:
    """FP32 forward: returns spike-count logits [B, classes]."""
    b = x.shape[0]
    spikes_t = encode_all(x, arch.timesteps)  # [T, B, K]

    if isinstance(arch, MlpArch):
        v0 = [jnp.zeros((b, n), jnp.float32) for n in arch.sizes[1:]]

        def step(vs, s_in):
            s = s_in
            new_vs = []
            for w, v in zip(params, vs):
                s, v2 = _lif_float(s @ w, v, arch.leak_shift)
                new_vs.append(v2)
            return new_vs, s

        _, outs = lax.scan(step, v0, spikes_t)
        return jnp.sum(outs, axis=0)

    side = arch.side
    c0, c1, c2 = arch.channels
    v0 = [
        jnp.zeros((b * side * side, c1), jnp.float32),
        jnp.zeros((b * (side // 2) * (side // 2), c2), jnp.float32),
        jnp.zeros((b, arch.classes), jnp.float32),
    ]

    def step(vs, s_in):
        s_img = s_in.reshape(b, side, side, c0)
        s1, v1 = _lif_float(_patches(s_img, c0, side) @ params[0], vs[0], arch.leak_shift)
        s1 = _maxpool2(s1.reshape(b, side, side, c1))
        h2 = side // 2
        s2, v2 = _lif_float(_patches(s1, c1, h2) @ params[1], vs[1], arch.leak_shift)
        s2 = _maxpool2(s2.reshape(b, h2, h2, c2))
        s3, v3 = _lif_float(s2.reshape(b, arch.fc_in) @ params[2], vs[2], arch.leak_shift)
        return [v1, v2, v3], s3

    _, outs = lax.scan(step, v0, spikes_t)
    return jnp.sum(outs, axis=0)


def loss_fn(params, arch: Arch, x, y) -> jnp.ndarray:
    """Cross-entropy on spike-count logits (counts are already ~[0, T])."""
    logits = forward_float(params, arch, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ----------------------------------------------------------------------
# Minimal Adam (optax is not installed in this environment).
# ----------------------------------------------------------------------


@dataclasses.dataclass
class AdamState:
    step: int
    m: list[jnp.ndarray]
    v: list[jnp.ndarray]


def adam_init(params: Sequence[jnp.ndarray]) -> AdamState:
    return AdamState(
        0,
        [jnp.zeros_like(p) for p in params],
        [jnp.zeros_like(p) for p in params],
    )


def adam_update(
    params, grads, state: AdamState, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8
):
    t = state.step + 1
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, state.m, state.v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1**t)
        vhat = v2 / (1 - b2**t)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(m2)
        new_v.append(v2)
    return new_p, AdamState(t, new_m, new_v)


def accuracy(params, arch: Arch, x: np.ndarray, y: np.ndarray, batch=256) -> float:
    """Batched FP32 accuracy on numpy data."""
    fwd = jax.jit(lambda p, xb: forward_float(p, arch, xb))
    hits = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i : i + batch])
        logits = np.asarray(fwd(params, xb))
        hits += int((logits.argmax(axis=1) == y[i : i + batch]).sum())
    return hits / len(x)
