"""Layer-adaptive precision search — the paper's future-work feature.

"Future work will explore layer-adaptive precision scaling for next-gen
edge AI systems" (§IV). This module implements it: a greedy search that
assigns each layer the lowest field width whose accuracy cost stays
within a budget.

Soundness of mixing: layers exchange only binary spikes, so a layer
quantized at width b_l with its own folded threshold is independent of
its neighbours' widths — a mixed network is exactly the per-layer
composition of the uniform QAT models' layers.

Search: start from all-INT8 (the accuracy ceiling), repeatedly try to
demote the layer with the largest memory saving 8->4->2; keep a demotion
if validation accuracy stays within ``epsilon`` of the all-INT8 model.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import model as qm
from .snn import Arch

BITS_LADDER = (8, 4, 2)


@dataclasses.dataclass
class MixedResult:
    model: qm.QuantModel
    bits_per_layer: list[int]
    accuracy: float
    int8_accuracy: float
    memory_bits: int


def build_mixed(
    params_by_bits: dict[int, list[np.ndarray]],
    arch: Arch,
    bits_per_layer: list[int],
) -> qm.QuantModel:
    """Compose a mixed model from per-width QAT'd parameter sets."""
    uniform = {
        b: qm.quantize_model(params_by_bits[b], arch, b, "lspine")
        for b in sorted(set(bits_per_layer))
    }
    layers = tuple(
        uniform[b].layers[i] for i, b in enumerate(bits_per_layer)
    )
    return qm.QuantModel(arch=arch, scheme="mixed", bits=0, layers=layers)


def greedy_mixed_search(
    params_by_bits: dict[int, list[np.ndarray]],
    arch: Arch,
    x_val: np.ndarray,
    y_val: np.ndarray,
    epsilon: float = 0.02,
) -> MixedResult:
    """Greedy layer-wise precision demotion under an accuracy budget."""
    n_layers = len(params_by_bits[8])
    bits = [8] * n_layers
    base_model = build_mixed(params_by_bits, arch, bits)
    int8_acc = qm.accuracy_int(base_model, x_val, y_val)
    floor = int8_acc - epsilon

    current_acc = int8_acc
    improved = True
    while improved:
        improved = False
        # candidate demotions, largest memory saving first
        candidates = []
        for i in range(n_layers):
            ladder = list(BITS_LADDER)
            pos = ladder.index(bits[i])
            if pos + 1 < len(ladder):
                trial = bits.copy()
                trial[i] = ladder[pos + 1]
                saving = (
                    build_mixed(params_by_bits, arch, bits).layers[i].memory_bits()
                    - build_mixed(params_by_bits, arch, trial).layers[i].memory_bits()
                )
                candidates.append((saving, i, ladder[pos + 1]))
        candidates.sort(reverse=True)
        for _, i, new_bits in candidates:
            trial = bits.copy()
            trial[i] = new_bits
            model = build_mixed(params_by_bits, arch, trial)
            acc = qm.accuracy_int(model, x_val, y_val)
            if acc >= floor:
                bits = trial
                current_acc = acc
                improved = True
                break  # re-rank savings after each accepted demotion

    final = build_mixed(params_by_bits, arch, bits)
    return MixedResult(
        model=final,
        bits_per_layer=bits,
        accuracy=current_acc,
        int8_accuracy=int8_acc,
        memory_bits=final.memory_bits(),
    )
