"""Post-training quantization schemes for the L-SPINE flow (Fig. 3/4).

Four schemes are implemented, matching the paper's quantization analysis:

- ``lspine``  — the proposed scheme: symmetric per-tensor quantization with
  an MSE-optimal clipping search, so the scale is chosen to minimize
  reconstruction error rather than to cover outliers. This is what lets
  INT2/INT4 keep accuracy in Fig. 4/5.
- ``stbp``    — STBP-style [14]: plain min-max symmetric round-to-nearest
  (scale covers the absolute max — outlier-dominated at low bit widths).
- ``admm``    — ADMM-style [15]: alternating projection refining (scale, q)
  to minimize ||W - s.q||^2, initialized from min-max.
- ``trunc``   — Truncation-based [16]: power-of-two scale and truncation
  toward zero (drops fraction bits, no rounding).

All schemes emit the same integer artifact: ``q`` in the two's-complement
INT{2,4,8} range plus one f32 scale per tensor, which then flows through
the shared packing contract (`kernels/packed.py`). The layer threshold is
re-folded into the integer domain: ``theta_int = round(theta_fp / s)``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .kernels.packed import pack_weights_np, qmin_qmax

SCHEMES = ("lspine", "stbp", "admm", "trunc")


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """One quantized weight tensor plus its dequantization scale."""

    q: np.ndarray  # int32, values within the INT{bits} range
    scale: float
    bits: int

    def dequant(self) -> np.ndarray:
        return self.q.astype(np.float32) * np.float32(self.scale)

    def packed(self) -> np.ndarray:
        """Pack along the last (output) axis; 2-D tensors only."""
        return pack_weights_np(self.q, self.bits)

    def memory_bits(self) -> int:
        """Storage cost of the packed representation (padding included)."""
        lanes = 32 // self.bits
        k, n = self.q.shape
        return k * (-(-n // lanes)) * 32


def _quantize_with_scale(w: np.ndarray, scale: float, bits: int) -> np.ndarray:
    lo, hi = qmin_qmax(bits)
    q = np.round(w / scale)
    return np.clip(q, lo, hi).astype(np.int32)


def quantize_stbp(w: np.ndarray, bits: int) -> QuantizedTensor:
    """Min-max symmetric round-to-nearest (STBP-style baseline)."""
    _, hi = qmin_qmax(bits)
    amax = float(np.abs(w).max())
    scale = amax / hi if amax > 0 else 1.0
    return QuantizedTensor(_quantize_with_scale(w, scale, bits), scale, bits)


def quantize_lspine(w: np.ndarray, bits: int, grid: int = 80) -> QuantizedTensor:
    """Proposed: grid-search the clipping scale that minimizes MSE.

    Searches ``scale = amax * r / qmax`` for r in (0, 1]; at 2 bits the
    optimum typically clips hard (r ~ 0.3-0.5), recovering most of the
    min-max scheme's loss.
    """
    _, hi = qmin_qmax(bits)
    amax = float(np.abs(w).max())
    if amax == 0.0:
        return QuantizedTensor(np.zeros_like(w, dtype=np.int32), 1.0, bits)
    best_q, best_scale, best_err = None, 1.0, np.inf
    for i in range(1, grid + 1):
        scale = amax * (i / grid) / hi
        q = _quantize_with_scale(w, scale, bits)
        err = float(np.mean((w - q * scale) ** 2))
        if err < best_err:
            best_q, best_scale, best_err = q, scale, err
    return QuantizedTensor(best_q, best_scale, bits)


def quantize_admm(w: np.ndarray, bits: int, iters: int = 12) -> QuantizedTensor:
    """ADMM-style alternating projection: fix q -> optimal s, fix s -> q."""
    _, hi = qmin_qmax(bits)
    amax = float(np.abs(w).max())
    scale = amax / hi if amax > 0 else 1.0
    q = _quantize_with_scale(w, scale, bits)
    for _ in range(iters):
        denom = float(np.sum(q.astype(np.float64) ** 2))
        if denom == 0.0:
            break
        scale = float(np.sum(w.astype(np.float64) * q) / denom)
        if scale <= 0.0:
            scale = amax / hi if amax > 0 else 1.0
            break
        q_next = _quantize_with_scale(w, scale, bits)
        if np.array_equal(q_next, q):
            break
        q = q_next
    return QuantizedTensor(q, scale, bits)


def quantize_trunc(w: np.ndarray, bits: int) -> QuantizedTensor:
    """Truncation baseline: power-of-two scale, truncate toward zero."""
    lo, hi = qmin_qmax(bits)
    amax = float(np.abs(w).max())
    if amax == 0.0:
        return QuantizedTensor(np.zeros_like(w, dtype=np.int32), 1.0, bits)
    # Smallest power-of-two scale whose range covers amax.
    scale = 2.0 ** np.ceil(np.log2(amax / hi))
    q = np.clip(np.trunc(w / scale), lo, hi).astype(np.int32)
    return QuantizedTensor(q, float(scale), bits)


_QUANTIZERS = {
    "lspine": quantize_lspine,
    "stbp": quantize_stbp,
    "admm": quantize_admm,
    "trunc": quantize_trunc,
}


def quantize(w: np.ndarray, bits: int, scheme: str = "lspine") -> QuantizedTensor:
    """Quantize a weight tensor with the named scheme."""
    try:
        fn = _QUANTIZERS[scheme]
    except KeyError:
        raise ValueError(f"unknown scheme {scheme!r}; pick one of {SCHEMES}")
    return fn(w, bits)


def fold_threshold(theta_fp: float, scale: float) -> int:
    """Fold the FP threshold into the layer's integer domain (>= 1)."""
    return max(1, int(round(theta_fp / scale)))
