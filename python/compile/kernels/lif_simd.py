"""L1 — Pallas kernel for the L-SPINE multi-precision SIMD LIF step.

One `pallas_call` implements one timestep of one LIF layer over a batch:
spike-gated synaptic accumulation from *bit-packed* weights, shift-based
leak, threshold, reset-by-subtraction. This is the NCE (Fig. 2 of the
paper) re-thought for a TPU-style memory hierarchy (DESIGN.md
§Hardware-Adaptation):

- the packed u32 weight block is the unit staged into VMEM (INT2 moves
  16x less HBM traffic than FP32 — the paper's memory-footprint win);
- field unpack is shifts/masks/xor-sub on the VPU (multiplier-less);
- spike gating is a masked accumulation (spikes are {0,1}, the dot
  contains no real multiplies);
- the grid tiles (batch x output) so each program's working set
  (spike rows + one packed weight tile + membrane tile) fits VMEM.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the *same* kernel is
what the rust runtime executes (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .packed import lanes_per_word


def _lif_kernel(
    spikes_ref,  # [Bt, K] int32
    w_ref,  # [K, NWt] uint32 packed
    v_ref,  # [Bt, Nt] int32
    out_ref,  # [Bt, Nt] int32 spikes
    v_out_ref,  # [Bt, Nt] int32
    *,
    bits: int,
    theta: int,
    leak_shift: int,
):
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    sign = jnp.int32(1 << (bits - 1))

    words = w_ref[...]  # [K, NWt]
    k, n_words = words.shape
    # SIMD field extract: shift/mask each of the `lanes` fields, then
    # xor-sub sign extension — exactly the datapath's unpack network.
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits).reshape(1, 1, lanes)
    fields = (words[:, :, None] >> shifts) & mask
    w = ((fields.astype(jnp.int32) ^ sign) - sign).reshape(k, n_words * lanes)

    spikes = spikes_ref[...]
    # Binary spikes: this dot is a spike-gated add tree, no multiplies in HW.
    i_syn = jnp.dot(spikes, w, preferred_element_type=jnp.int32)

    v = v_ref[...]
    v_new = v - (v >> jnp.int32(leak_shift)) + i_syn
    fired = v_new >= jnp.int32(theta)
    out_ref[...] = fired.astype(jnp.int32)
    v_out_ref[...] = v_new - fired.astype(jnp.int32) * jnp.int32(theta)


def _tile(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (keeps the grid exact)."""
    t = min(n, pref)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(
    jax.jit, static_argnames=("bits", "n_out", "theta", "leak_shift")
)
def lif_simd_step(
    spikes: jnp.ndarray,  # [B, K] int32 {0,1}
    packed_w: jnp.ndarray,  # [K, Nw] uint32
    v: jnp.ndarray,  # [B, N] int32
    *,
    bits: int,
    n_out: int,
    theta: int,
    leak_shift: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One LIF layer timestep via the pallas NCE kernel.

    ``n_out`` may be smaller than ``Nw * lanes``; the padded tail columns
    are computed (their packed fields are zero) and sliced off.
    """
    lanes = lanes_per_word(bits)
    b, k = spikes.shape
    n_words = packed_w.shape[1]
    n_padded = n_words * lanes
    if v.shape[1] != n_out:
        raise ValueError("membrane width must equal n_out")

    # Pad membrane to the packed width so tiles line up with words.
    v_padded = (
        v
        if n_padded == n_out
        else jnp.pad(v, ((0, 0), (0, n_padded - n_out)))
    )

    bt = _tile(b, 128)
    # Output tile must be word-aligned: choose in packed-word units.
    nwt = _tile(n_words, max(1, 512 // lanes))
    nt = nwt * lanes

    grid = (b // bt, n_words // nwt)
    kernel = functools.partial(
        _lif_kernel, bits=bits, theta=theta, leak_shift=leak_shift
    )
    out, v_next = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, nwt), lambda i, j: (0, j)),
            pl.BlockSpec((bt, nt), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bt, nt), lambda i, j: (i, j)),
            pl.BlockSpec((bt, nt), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_padded), jnp.int32),
            jax.ShapeDtypeStruct((b, n_padded), jnp.int32),
        ],
        interpret=True,
    )(spikes, packed_w, v_padded)
    return out[:, :n_out], v_next[:, :n_out]
