"""Bit-packing contract shared by L1 (pallas), L2 (jax) and L3 (rust).

Signed fields of width ``b`` in {2, 4, 8} are stored two's-complement at bit
offset ``b*i`` of a little-endian uint32 word, ``lanes = 32 // b`` fields per
word. This is the storage layout of the paper's SIMD datapath: one 32-bit
word feeds 16 INT2 / 8 INT4 / 4 INT8 lanes of the NCE.

The rust mirror is ``rust/src/nce/simd.rs``; golden vectors in
``python/tests/test_packed.py`` and ``rust/src/nce/simd.rs`` tests pin the
two implementations to each other.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

SUPPORTED_BITS = (2, 4, 8)


def lanes_per_word(bits: int) -> int:
    """Number of packed fields in one u32 storage word."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported field width: {bits}")
    return 32 // bits


def qmin_qmax(bits: int) -> tuple[int, int]:
    """Two's-complement range of a ``bits``-wide signed field."""
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def pack_weights_np(q: np.ndarray, bits: int) -> np.ndarray:
    """Pack signed integer weights ``q [K, N]`` along the output axis N.

    Returns uint32 ``[K, ceil(N / lanes)]``. N is zero-padded to a full word;
    zero fields contribute nothing to accumulation, so padding is harmless.
    """
    lanes = lanes_per_word(bits)
    lo, hi = qmin_qmax(bits)
    if q.ndim != 2:
        raise ValueError("pack_weights_np expects a 2-D [K, N] array")
    if q.min(initial=0) < lo or q.max(initial=0) > hi:
        raise ValueError(f"values out of INT{bits} range [{lo}, {hi}]")
    k, n = q.shape
    n_words = -(-n // lanes)
    padded = np.zeros((k, n_words * lanes), dtype=np.int64)
    padded[:, :n] = q.astype(np.int64)
    mask = (1 << bits) - 1
    fields = (padded & mask).reshape(k, n_words, lanes)
    shifts = (np.arange(lanes, dtype=np.uint64) * bits).reshape(1, 1, lanes)
    words = np.bitwise_or.reduce(
        (fields.astype(np.uint64) << shifts).astype(np.uint64), axis=2
    )
    return words.astype(np.uint32)


def unpack_weights_np(words: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of :func:`pack_weights_np`; returns int32 ``[K, n]``."""
    lanes = lanes_per_word(bits)
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    k, n_words = words.shape
    shifts = (np.arange(lanes, dtype=np.uint32) * bits).reshape(1, 1, lanes)
    fields = (words[:, :, None] >> shifts) & mask
    fields = (fields.astype(np.int64) ^ sign) - sign
    return fields.reshape(k, n_words * lanes)[:, :n].astype(np.int32)


def unpack_weights_jnp(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """jnp unpack used inside the L2 graph and the pallas kernel.

    Multiplier-less on hardware: shifts, masks and an xor/sub sign-extend.
    """
    lanes = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    sign = jnp.int32(1 << (bits - 1))
    k, n_words = words.shape
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits).reshape(1, 1, lanes)
    fields = (words[:, :, None] >> shifts) & mask
    fields = (fields.astype(jnp.int32) ^ sign) - sign
    return fields.reshape(k, n_words * lanes)[:, :n]
