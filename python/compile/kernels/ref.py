"""Pure-jnp oracle for the L1 kernel — the CORE correctness reference.

Implements the integer LIF step of DESIGN.md §Key bit-level contracts with
no pallas, no packing tricks beyond the shared unpack helper. The pallas
kernel (`lif_simd.py`), the AOT'd L2 graph, and the rust `model::engine`
must all agree with this bit-for-bit (asserted by pytest + hypothesis and
by the rust integration tests).
"""

from __future__ import annotations

import jax.numpy as jnp

from .packed import unpack_weights_jnp


def lif_step_ref(
    spikes: jnp.ndarray,  # [B, K] int32 in {0, 1}
    packed_w: jnp.ndarray,  # [K, Nw] uint32
    v: jnp.ndarray,  # [B, N] int32 membrane potential
    *,
    bits: int,
    n_out: int,
    theta: int,
    leak_shift: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One integer LIF timestep. Returns (out_spikes [B,N], v_next [B,N]).

    Dynamics (all int32, shifts arithmetic):
        I      = spikes @ unpack(packed_w)        # spike-gated accumulation
        V'     = V - (V >> leak_shift) + I        # shift-based leak
        spike  = V' >= theta
        V''    = V' - theta * spike               # reset by subtraction
    """
    w = unpack_weights_jnp(packed_w, bits, n_out)  # [K, N] int32
    i_syn = jnp.dot(spikes.astype(jnp.int32), w)  # binary spikes: adds only
    v_leaked = v - (v >> jnp.int32(leak_shift))
    v_new = v_leaked + i_syn
    out = (v_new >= jnp.int32(theta)).astype(jnp.int32)
    v_reset = v_new - out * jnp.int32(theta)
    return out, v_reset


def encode_step_ref(
    x_u8: jnp.ndarray,  # [B, K] int32 holding u8 values 0..255
    t: int,
) -> jnp.ndarray:
    """Accumulate-and-fire rate encoder, timestep ``t`` (0-based).

    Emits a deterministic rate code: after t+1 steps exactly
    ``(x_u8 * (t+1)) >> 8`` spikes have fired, so each step fires
    ``cum(t+1) - cum(t)`` in {0, 1}. Integer-exact mirror of the rust
    encoder (`rust/src/encode/`).
    """
    c1 = (x_u8 * jnp.int32(t + 1)) >> jnp.int32(8)
    c0 = (x_u8 * jnp.int32(t)) >> jnp.int32(8)
    return (c1 - c0).astype(jnp.int32)
