"""AOT entrypoint: train -> quantize -> evaluate -> export (Fig. 3 flow).

Emits, under ``artifacts/``:

- ``{arch}_{scheme}_int{bits}.w.bin``  — packed integer weights (LSPW) for
  every scheme x bits combination (the rust engine + Fig.4 regenerator).
- ``{arch}_int{bits}_b{B}.hlo.txt``    — HLO *text* of the integer
  inference graph (lspine scheme) at batch B, pallas kernel inside.
- ``{arch}_fp32_b{B}.hlo.txt``         — FP32 baseline graph.
- ``testset.bin``                      — the exact test split (LSPD).
- ``manifest.json``                    — everything the rust side needs:
  arch descriptions, artifact index, per-config accuracy/memory (Fig.4/5
  source data), training loss curves.

HLO text (NOT ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the rust
``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONLY here — never on the request path. `make artifacts` is a
no-op when inputs are unchanged (Makefile dependency tracking), and the
trained FP32 params are cached under ``artifacts/cache/``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import mixed as mx
from . import model as qm
from . import quantize as qz
from . import snn
from .dataset import make_dataset
from .train import qat_finetune, train

BITS = (2, 4, 8)
HLO_BATCHES = (1, 32)
ARCHS: tuple[snn.Arch, ...] = (snn.MlpArch(), snn.ConvArch())


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # constant arrays as `{...}`, silently replacing the embedded packed
    # weights with garbage when the text is re-parsed on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_int_graph(model: qm.QuantModel, batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, _input_dim(model.arch)), np.float32)
    fn = lambda x: (qm.forward_int(model, x),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_fp32_graph(params, arch: snn.Arch, batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, _input_dim(arch)), np.float32)
    fn = lambda x: (snn.forward_float(params, arch, x),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def _input_dim(arch: snn.Arch) -> int:
    if isinstance(arch, snn.MlpArch):
        return arch.sizes[0]
    return arch.side * arch.side * arch.channels[0]


def _arch_json(arch: snn.Arch) -> dict:
    if isinstance(arch, snn.MlpArch):
        return {
            "kind": "mlp",
            "sizes": list(arch.sizes),
            "timesteps": arch.timesteps,
            "leak_shift": arch.leak_shift,
        }
    return {
        "kind": "convnet",
        "side": arch.side,
        "channels": list(arch.channels),
        "classes": arch.classes,
        "timesteps": arch.timesteps,
        "leak_shift": arch.leak_shift,
    }


# Per-arch training budgets: the convnet needs a longer schedule to
# converge (see EXPERIMENTS.md training log).
TRAIN_CFG = {"mlp": (400, 2e-3), "convnet": (1200, 3e-3)}


def _cached_train(arch: snn.Arch, data, cache_dir: pathlib.Path, steps: int):
    cache = cache_dir / f"{arch.name}_trained.npz"
    if cache.exists():
        z = np.load(cache, allow_pickle=False)
        n = int(z["n_layers"])
        params = [z[f"w{i}"] for i in range(n)]
        return params, list(z["loss_curve"]), float(z["test_acc"]), float(
            z["train_acc"]
        )
    steps, lr = TRAIN_CFG.get(arch.name, (steps, 2e-3))
    print(f"[aot] training {arch.name} ({steps} steps, lr={lr})...")
    t0 = time.time()
    res = train(arch, data, steps=steps, lr=lr, verbose=True)
    print(
        f"[aot] {arch.name}: train_acc={res.train_acc:.4f} "
        f"test_acc={res.test_acc:.4f} ({time.time() - t0:.1f}s)"
    )
    np.savez(
        cache,
        n_layers=len(res.params),
        loss_curve=np.asarray(res.loss_curve, dtype=np.float32),
        test_acc=res.test_acc,
        train_acc=res.train_acc,
        **{f"w{i}": p for i, p in enumerate(res.params)},
    )
    return res.params, res.loss_curve, res.test_acc, res.train_acc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--fast", action="store_true", help="mlp only, 120 steps")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    cache = out / "cache"
    cache.mkdir(exist_ok=True)

    data = make_dataset()
    qm.write_dataset(str(out / "testset.bin"), data.x_test, data.y_test)

    archs = (snn.MlpArch(),) if args.fast else ARCHS
    steps = 120 if args.fast else args.steps

    manifest: dict = {
        "format_version": qm.FORMAT_VERSION,
        "dataset": {
            "file": "testset.bin",
            "n_test": len(data.x_test),
            "input_dim": data.input_dim,
            "classes": data.num_classes,
        },
        "models": {},
    }

    for arch in archs:
        params, loss_curve, fp32_test, fp32_train = _cached_train(
            arch, data, cache, steps
        )
        entry: dict = {
            "arch": _arch_json(arch),
            "training": {
                "steps": steps,
                "loss_curve": [round(float(x), 4) for x in loss_curve],
                "fp32_train_acc": fp32_train,
                "fp32_test_acc": fp32_test,
            },
            "fp32": {"hlo": {}},
            "quant": {},
            "hlo": {},
        }

        # FP32 weight memory = params * 32 bits (Fig. 4 reference point).
        entry["fp32"]["memory_bits"] = int(sum(p.size for p in params) * 32)

        # The proposed flow refines low-bit configs with brief QAT
        # (straight-through estimator, fixed MSE scales); baselines are
        # pure PTQ. Cached alongside the FP32 params.
        lspine_params: dict[int, list[np.ndarray]] = {}
        for bits in BITS:
            qat_cache = cache / f"{arch.name}_qat_int{bits}.npz"
            if qat_cache.exists():
                z = np.load(qat_cache)
                lspine_params[bits] = [z[f"w{i}"] for i in range(len(params))]
            else:
                print(f"[aot] QAT refinement {arch.name} INT{bits}...")
                lspine_params[bits] = qat_finetune(params, arch, data, bits)
                np.savez(
                    qat_cache,
                    **{f"w{i}": p for i, p in enumerate(lspine_params[bits])},
                )

        # --- quantization sweep: every scheme x bits (Fig. 4 + Fig. 5) ---
        for scheme in qz.SCHEMES:
            entry["quant"][scheme] = {}
            for bits in BITS:
                src = lspine_params[bits] if scheme == "lspine" else params
                model = qm.quantize_model(src, arch, bits, scheme)
                acc = qm.accuracy_int(model, data.x_test, data.y_test)
                wfile = f"{arch.name}_{scheme}_int{bits}.w.bin"
                qm.write_weights(str(out / wfile), model)
                entry["quant"][scheme][str(bits)] = {
                    "accuracy": acc,
                    "memory_bits": model.memory_bits(),
                    "weights": wfile,
                    "scales": [l.scale for l in model.layers],
                    "thetas": [l.theta for l in model.layers],
                }
                print(
                    f"[aot] {arch.name} {scheme:6s} INT{bits}: "
                    f"acc={acc:.4f} mem={model.memory_bits() // 8}B"
                )

        # --- layer-adaptive precision (the paper's future-work feature) ---
        # greedy demotion on a held-out slice of the TRAIN set; accuracy
        # reported on the test set (no leakage into the search).
        mixed = mx.greedy_mixed_search(
            lspine_params, arch, data.x_train[:512], data.y_train[:512]
        )
        mixed_test_acc = qm.accuracy_int(mixed.model, data.x_test, data.y_test)
        wfile = f"{arch.name}_mixed.w.bin"
        qm.write_weights(str(out / wfile), mixed.model)
        mixed_hlo = {}
        for b in HLO_BATCHES:
            name = f"{arch.name}_mixed_b{b}.hlo.txt"
            (out / name).write_text(lower_int_graph(mixed.model, b))
            mixed_hlo[str(b)] = name
        entry["mixed"] = {
            "bits_per_layer": mixed.bits_per_layer,
            "accuracy": mixed_test_acc,
            "memory_bits": mixed.memory_bits,
            "weights": wfile,
            "hlo": mixed_hlo,
        }
        print(
            f"[aot] {arch.name} mixed precision {mixed.bits_per_layer}: "
            f"acc={mixed_test_acc:.4f} mem={mixed.memory_bits // 8}B "
            f"(INT8 uniform: {entry['quant']['lspine']['8']['accuracy']:.4f})"
        )

        # --- AOT lowering: lspine scheme only (the deployed configs) ---
        for bits in BITS:
            model = qm.quantize_model(lspine_params[bits], arch, bits, "lspine")
            entry["hlo"][f"int{bits}"] = {}
            for b in HLO_BATCHES:
                name = f"{arch.name}_int{bits}_b{b}.hlo.txt"
                (out / name).write_text(lower_int_graph(model, b))
                entry["hlo"][f"int{bits}"][str(b)] = name
                print(f"[aot] lowered {name}")
        for b in HLO_BATCHES:
            name = f"{arch.name}_fp32_b{b}.hlo.txt"
            (out / name).write_text(lower_fp32_graph(params, arch, b))
            entry["fp32"]["hlo"][str(b)] = name
            print(f"[aot] lowered {name}")

        manifest["models"][arch.name] = entry

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
