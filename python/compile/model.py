"""L2 — quantized integer inference graphs (the AOT'd compute).

`QuantModel` holds the integer artifact of one (arch, scheme, bits)
combination: packed weights, per-layer scales and folded integer
thresholds. `forward_int` is the inference graph that gets lowered to HLO:
a `lax.scan` over timesteps whose body encodes the input and pushes spikes
through one pallas NCE step (`kernels.lif_simd`) per layer — exactly the
computation the rust cycle simulator accounts for.

`forward_int_ref` is the same graph on the pure-jnp oracle; pytest pins
kernel == oracle, and the rust integration tests pin PJRT(HLO) == rust
engine == oracle.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import quantize as qz
from .kernels import ref as kref
from .kernels.lif_simd import lif_simd_step
from .snn import Arch, ConvArch, MlpArch, THETA_FP


@dataclasses.dataclass(frozen=True)
class QuantLayer:
    """One LIF layer in the integer domain."""

    packed: np.ndarray  # uint32 [K, n_words]
    bits: int
    k_in: int
    n_out: int
    scale: float
    theta: int  # folded integer threshold

    @property
    def n_words(self) -> int:
        return self.packed.shape[1]

    def memory_bits(self) -> int:
        return self.packed.size * 32


@dataclasses.dataclass(frozen=True)
class QuantModel:
    arch: Arch
    scheme: str
    bits: int
    layers: tuple[QuantLayer, ...]

    def memory_bits(self) -> int:
        return sum(l.memory_bits() for l in self.layers)


def quantize_model(
    params: Sequence[np.ndarray], arch: Arch, bits: int, scheme: str
) -> QuantModel:
    """Post-training quantize FP32 params into a `QuantModel` (Fig. 3 flow)."""
    layers = []
    for w in params:
        w = np.asarray(w, dtype=np.float32)
        qt = qz.quantize(w, bits, scheme)
        layers.append(
            QuantLayer(
                packed=qt.packed(),
                bits=bits,
                k_in=w.shape[0],
                n_out=w.shape[1],
                scale=qt.scale,
                theta=qz.fold_threshold(THETA_FP, qt.scale),
            )
        )
    return QuantModel(arch=arch, scheme=scheme, bits=bits, layers=tuple(layers))


StepFn = Callable[..., tuple[jnp.ndarray, jnp.ndarray]]


def _maxpool2_int(s_img: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool on binary int32 spikes (== OR)."""
    b, h, w, c = s_img.shape
    s = s_img.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(jnp.max(s, axis=4), axis=2)


def _patches_int(s_img: jnp.ndarray, ch: int, side: int) -> jnp.ndarray:
    """im2col on int32 spikes: [B,side,side,ch] -> [B*side*side, 9*ch]."""
    b = s_img.shape[0]
    x_nchw = jnp.transpose(s_img, (0, 3, 1, 2))
    p = lax.conv_general_dilated_patches(x_nchw, (3, 3), (1, 1), "SAME")
    p = jnp.transpose(p, (0, 2, 3, 1))
    return p.reshape(b * side * side, ch * 9)


def _encode_t(x_u8: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Accumulate-and-fire rate encoder with a traced timestep index."""
    c1 = (x_u8 * (t + 1)) >> 8
    c0 = (x_u8 * t) >> 8
    return (c1 - c0).astype(jnp.int32)


def _forward_int(
    model: QuantModel, x: jnp.ndarray, step_fn: StepFn
) -> jnp.ndarray:
    """Integer forward pass -> spike counts [B, classes] (int32)."""
    arch = model.arch
    b = x.shape[0]
    x_u8 = jnp.clip(jnp.round(x * 255.0), 0, 255).astype(jnp.int32)
    packed = [jnp.asarray(l.packed) for l in model.layers]

    def layer_step(idx: int, spikes: jnp.ndarray, v: jnp.ndarray):
        l = model.layers[idx]
        return step_fn(
            spikes,
            packed[idx],
            v,
            bits=l.bits,
            n_out=l.n_out,
            theta=l.theta,
            leak_shift=arch.leak_shift,
        )

    if isinstance(arch, MlpArch):
        v0 = [jnp.zeros((b, n), jnp.int32) for n in arch.sizes[1:]]

        # lax.scan over the timestep index: the encoder stays inside the
        # lowered graph, so no [T, B, K] spike tensor is materialized.
        def step_t(vs, t):
            s = _encode_t(x_u8, t)
            new_vs = []
            for i in range(len(model.layers)):
                s, v2 = layer_step(i, s, vs[i])
                new_vs.append(v2)
            return new_vs, s

        _, outs = lax.scan(step_t, v0, jnp.arange(arch.timesteps))
        return jnp.sum(outs, axis=0)

    side = arch.side
    c0, c1, c2 = arch.channels
    v0 = [
        jnp.zeros((b * side * side, c1), jnp.int32),
        jnp.zeros((b * (side // 2) * (side // 2), c2), jnp.int32),
        jnp.zeros((b, arch.classes), jnp.int32),
    ]

    def step_t(vs, t):
        s_in = _encode_t(x_u8, t)
        s_img = s_in.reshape(b, side, side, c0)
        s1, v1 = layer_step(0, _patches_int(s_img, c0, side), vs[0])
        s1 = _maxpool2_int(s1.reshape(b, side, side, c1))
        h2 = side // 2
        s2, v2 = layer_step(1, _patches_int(s1, c1, h2), vs[1])
        s2 = _maxpool2_int(s2.reshape(b, h2, h2, c2))
        s3, v3 = layer_step(2, s2.reshape(b, arch.fc_in), vs[2])
        return [v1, v2, v3], s3

    _, outs = lax.scan(step_t, v0, jnp.arange(arch.timesteps))
    return jnp.sum(outs, axis=0)


def forward_int(model: QuantModel, x: jnp.ndarray) -> jnp.ndarray:
    """Inference via the pallas NCE kernel — this is what gets AOT'd."""
    return _forward_int(model, x, lif_simd_step)


def forward_int_ref(model: QuantModel, x: jnp.ndarray) -> jnp.ndarray:
    """Inference via the pure-jnp oracle (tests / fast sweeps)."""
    return _forward_int(model, x, kref.lif_step_ref)


def accuracy_int(
    model: QuantModel,
    x: np.ndarray,
    y: np.ndarray,
    batch: int = 256,
    use_kernel: bool = False,
) -> float:
    """Top-1 accuracy of the integer model on numpy data."""
    fwd_raw = forward_int if use_kernel else forward_int_ref
    fwd = jax.jit(lambda xb: fwd_raw(model, xb))
    hits = 0
    for i in range(0, len(x), batch):
        xb = x[i : i + batch]
        n = len(xb)
        if n < batch:  # static shapes: pad the tail batch
            xb = np.concatenate([xb, np.zeros((batch - n, x.shape[1]), x.dtype)])
        counts = np.asarray(fwd(jnp.asarray(xb)))[:n]
        hits += int((counts.argmax(axis=1) == y[i : i + n]).sum())
    return hits / len(x)


# ----------------------------------------------------------------------
# Binary artifact formats consumed by the rust side (rust/src/model/io.rs)
# ----------------------------------------------------------------------

WEIGHTS_MAGIC = b"LSPW"
DATASET_MAGIC = b"LSPD"
FORMAT_VERSION = 1


def write_weights(path: str, model: QuantModel) -> None:
    """LSPW format: magic, (version, n_layers, timesteps, leak_shift) u32,
    then per layer: (bits, k_in, n_out, n_words) u32, scale f32, theta i32,
    then k_in*n_words packed u32 words, row-major. Little-endian."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(
            struct.pack(
                "<IIII",
                FORMAT_VERSION,
                len(model.layers),
                model.arch.timesteps,
                model.arch.leak_shift,
            )
        )
        for l in model.layers:
            f.write(struct.pack("<IIII", l.bits, l.k_in, l.n_out, l.n_words))
            f.write(struct.pack("<fi", l.scale, l.theta))
            f.write(np.ascontiguousarray(l.packed, dtype="<u4").tobytes())


def write_dataset(path: str, x: np.ndarray, y: np.ndarray) -> None:
    """LSPD format: magic, (version, n, dim, classes) u32, n*dim u8 pixels
    (the exact u8 values the encoder consumes), n u8 labels."""
    x_u8 = np.clip(np.round(x * 255.0), 0, 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(DATASET_MAGIC)
        f.write(
            struct.pack(
                "<IIII", FORMAT_VERSION, len(x), x.shape[1], int(y.max()) + 1
            )
        )
        f.write(x_u8.tobytes())
        f.write(y.astype(np.uint8).tobytes())
