"""Build-time surrogate-gradient training (Fig. 3, left column).

Trains the FP32 spiking networks that the quantization flow consumes.
Runs once per `make artifacts`; results are cached as .npz keyed by the
architecture so re-running the AOT step is cheap. The loss curve is saved
into the manifest and transcribed to EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import snn
from .dataset import Dataset
from .snn import Arch


@dataclasses.dataclass
class TrainResult:
    params: list[np.ndarray]
    loss_curve: list[float]  # loss every `log_every` steps
    train_acc: float
    test_acc: float
    steps: int


def qat_finetune(
    params: list[np.ndarray],
    arch: Arch,
    data: Dataset,
    bits: int,
    steps: int = 200,
    lr: float = 5e-4,
    batch: int = 128,
    seed: int = 3,
) -> list[np.ndarray]:
    """Brief quantization-aware refinement for the proposed scheme.

    Fake-quantizes weights in the forward pass (straight-through
    estimator) with *fixed* per-tensor MSE-optimal scales from the PTQ
    search, and fine-tunes for a few hundred steps. This is what lets the
    proposed L-SPINE flow keep INT2/INT4 accuracy where pure PTQ
    collapses (Fig. 4's 'proposed' curve); the STBP/ADMM/Trunc baselines
    stay pure PTQ.
    """
    from .quantize import quantize_lspine

    scales = [quantize_lspine(np.asarray(p), bits).scale for p in params]
    hi = (1 << (bits - 1)) - 1
    lo = -(hi + 1)

    def fake_quant(w, s):
        q = jnp.clip(jnp.round(w / s), lo, hi)
        return w + jax.lax.stop_gradient(q * s - w)

    def loss(ps, x, y):
        wq = [fake_quant(w, s) for w, s in zip(ps, scales)]
        return snn.loss_fn(wq, arch, x, y)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    ps = [jnp.asarray(p) for p in params]
    opt = snn.adam_init(ps)
    rng = np.random.default_rng(seed)
    n = len(data.x_train)
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        _, grads = grad_fn(
            ps, jnp.asarray(data.x_train[idx]), jnp.asarray(data.y_train[idx])
        )
        ps, opt = snn.adam_update(ps, grads, opt, lr=lr)
    return [np.asarray(p) for p in ps]


def train(
    arch: Arch,
    data: Dataset,
    steps: int = 400,
    batch: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 20,
    verbose: bool = False,
) -> TrainResult:
    """BPTT + triangular surrogate; minimal Adam; deterministic batches."""
    params = snn.init_params(arch, seed=seed)
    opt = snn.adam_init(params)
    rng = np.random.default_rng(seed + 1)

    grad_fn = jax.jit(
        jax.value_and_grad(lambda p, x, y: snn.loss_fn(p, arch, x, y))
    )

    loss_curve: list[float] = []
    n = len(data.x_train)
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        xb = jnp.asarray(data.x_train[idx])
        yb = jnp.asarray(data.y_train[idx])
        loss, grads = grad_fn(params, xb, yb)
        params, opt = snn.adam_update(params, grads, opt, lr=lr)
        if step % log_every == 0 or step == steps - 1:
            loss_curve.append(float(loss))
            if verbose:
                print(f"  step {step:4d}  loss {float(loss):.4f}")

    train_acc = snn.accuracy(params, arch, data.x_train[:1024], data.y_train[:1024])
    test_acc = snn.accuracy(params, arch, data.x_test, data.y_test)
    return TrainResult(
        params=[np.asarray(p) for p in params],
        loss_curve=loss_curve,
        train_acc=train_acc,
        test_acc=test_acc,
        steps=steps,
    )
