"""Deterministic synthetic vision dataset for the L-SPINE reproduction.

The paper evaluates quantized SNNs on standard vision workloads; we have no
dataset access in this environment, so we substitute a deterministic
synthetic pattern-classification task (see DESIGN.md §Hardware substitution).
The task is constructed so that quantization *trends* are reproduced:
FP32/INT8 accuracy is high, INT4 degrades gracefully, INT2 visibly but
usefully. Classes are smoothed random prototypes plus per-sample noise,
contrast jitter, and translation, which makes the decision boundary depend
on fine weight values (hence sensitive to aggressive quantization).

Everything is seeded; two calls with the same arguments produce bit-equal
arrays. The test split is exported to `artifacts/` so the rust engine
evaluates the *same* samples the python flow reports.
"""

from __future__ import annotations

import dataclasses

import numpy as np

IMG_SIDE = 16
NUM_CLASSES = 10
INPUT_DIM = IMG_SIDE * IMG_SIDE


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A train/test split of flattened images in [0, 1]."""

    x_train: np.ndarray  # [n_train, 256] float32 in [0, 1]
    y_train: np.ndarray  # [n_train] int32
    x_test: np.ndarray  # [n_test, 256] float32
    y_test: np.ndarray  # [n_test] int32

    @property
    def input_dim(self) -> int:
        return self.x_train.shape[1]

    @property
    def num_classes(self) -> int:
        return NUM_CLASSES


def _smooth(img: np.ndarray) -> np.ndarray:
    """3x3 box filter with edge clamping — keeps prototypes band-limited."""
    out = np.zeros_like(img)
    n = np.zeros_like(img)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            ys = slice(max(0, dy), IMG_SIDE + min(0, dy))
            xs = slice(max(0, dx), IMG_SIDE + min(0, dx))
            yd = slice(max(0, -dy), IMG_SIDE + min(0, -dy))
            xd = slice(max(0, -dx), IMG_SIDE + min(0, -dx))
            out[yd, xd] += img[ys, xs]
            n[yd, xd] += 1.0
    return out / n


def _prototypes(rng: np.random.Generator) -> np.ndarray:
    """NUM_CLASSES smoothed pseudo-random prototype images in [0, 1]."""
    protos = np.empty((NUM_CLASSES, IMG_SIDE, IMG_SIDE), dtype=np.float32)
    for c in range(NUM_CLASSES):
        raw = rng.random((IMG_SIDE, IMG_SIDE)).astype(np.float32)
        img = _smooth(_smooth(raw))
        # Normalize to full [0, 1] range so rate coding has dynamic range.
        img = (img - img.min()) / (img.max() - img.min() + 1e-9)
        protos[c] = img
    return protos


def _translate(img: np.ndarray, dy: int, dx: int) -> np.ndarray:
    """Shift with zero fill; |dy|,|dx| <= 2."""
    out = np.zeros_like(img)
    ys = slice(max(0, dy), IMG_SIDE + min(0, dy))
    xs = slice(max(0, dx), IMG_SIDE + min(0, dx))
    yd = slice(max(0, -dy), IMG_SIDE + min(0, -dy))
    xd = slice(max(0, -dx), IMG_SIDE + min(0, -dx))
    out[yd, xd] = img[ys, xs]
    return out


def _sample_split(
    protos: np.ndarray,
    n: int,
    rng: np.random.Generator,
    noise: float,
) -> tuple[np.ndarray, np.ndarray]:
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    x = np.empty((n, IMG_SIDE, IMG_SIDE), dtype=np.float32)
    for i in range(n):
        img = protos[y[i]]
        dy, dx = rng.integers(-2, 3, size=2)
        img = _translate(img, int(dy), int(dx))
        contrast = 0.7 + 0.6 * rng.random()
        brightness = 0.15 * (rng.random() - 0.5)
        img = img * contrast + brightness
        img = img + rng.normal(0.0, noise, size=img.shape)
        x[i] = np.clip(img, 0.0, 1.0)
    return x.reshape(n, INPUT_DIM).astype(np.float32), y


def make_dataset(
    n_train: int = 4096,
    n_test: int = 1024,
    noise: float = 0.18,
    seed: int = 7,
) -> Dataset:
    """Build the deterministic synthetic dataset used by every experiment."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng)
    x_tr, y_tr = _sample_split(protos, n_train, rng, noise)
    x_te, y_te = _sample_split(protos, n_test, rng, noise)
    return Dataset(x_train=x_tr, y_train=y_tr, x_test=x_te, y_test=y_te)
