"""Packing contract tests + golden vectors pinning python <-> rust."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.packed import (
    lanes_per_word,
    pack_weights_np,
    qmin_qmax,
    unpack_weights_np,
    unpack_weights_jnp,
)


@pytest.mark.parametrize("bits,lanes", [(2, 16), (4, 8), (8, 4)])
def test_lanes(bits, lanes):
    assert lanes_per_word(bits) == lanes


def test_lanes_rejects_bad_width():
    for bad in (1, 3, 5, 16, 32):
        with pytest.raises(ValueError):
            lanes_per_word(bad)


@pytest.mark.parametrize("bits,lo,hi", [(2, -2, 1), (4, -8, 7), (8, -128, 127)])
def test_qrange(bits, lo, hi):
    assert qmin_qmax(bits) == (lo, hi)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("k,n", [(1, 1), (3, 5), (16, 32), (7, 33)])
def test_roundtrip(bits, k, n):
    rng = np.random.default_rng(bits * 100 + k + n)
    lo, hi = qmin_qmax(bits)
    q = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int32)
    words = pack_weights_np(q, bits)
    assert words.dtype == np.uint32
    assert words.shape == (k, -(-n // lanes_per_word(bits)))
    assert (unpack_weights_np(words, bits, n) == q).all()


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_jnp_unpack_matches_np(bits):
    rng = np.random.default_rng(9)
    lo, hi = qmin_qmax(bits)
    q = rng.integers(lo, hi + 1, size=(13, 29)).astype(np.int32)
    words = pack_weights_np(q, bits)
    import jax.numpy as jnp

    out = np.asarray(unpack_weights_jnp(jnp.asarray(words), bits, 29))
    assert (out == q).all()


def test_pack_rejects_out_of_range():
    with pytest.raises(ValueError):
        pack_weights_np(np.array([[2]], dtype=np.int32), 2)
    with pytest.raises(ValueError):
        pack_weights_np(np.array([[-9]], dtype=np.int32), 4)


def test_pack_rejects_wrong_ndim():
    with pytest.raises(ValueError):
        pack_weights_np(np.zeros(4, dtype=np.int32), 2)


def test_padding_fields_are_zero():
    # n=3 with INT8 -> one word with the 4th field zero.
    q = np.array([[-1, 2, -3]], dtype=np.int32)
    w = pack_weights_np(q, 8)
    assert w.shape == (1, 1)
    assert (w[0, 0] >> 24) & 0xFF == 0
    # padded columns unpack to 0
    full = unpack_weights_np(w, 8, 4)
    assert full[0, 3] == 0


# Golden vectors: these exact words are also asserted by
# rust/src/nce/simd.rs::tests::golden_vectors — keep them in sync.
GOLDEN = [
    # (bits, row of q values, expected packed u32 words)
    (2, [-2, -1, 0, 1] * 4, [0x4E4E4E4E]),
    (4, [-8, -1, 0, 7, 3, -4, 1, 2], [0x21C370F8]),
    (8, [-128, -1, 0, 127], [0x7F00FF80]),
    (8, [1, 2, 3, 4, 5], [0x04030201, 0x00000005]),
]


@pytest.mark.parametrize("bits,row,words", GOLDEN)
def test_golden_vectors(bits, row, words):
    got = pack_weights_np(np.array([row], dtype=np.int32), bits)
    assert [int(w) for w in got[0]] == words


@settings(max_examples=60, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 24),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_property(bits, k, n, seed):
    rng = np.random.default_rng(seed)
    lo, hi = qmin_qmax(bits)
    q = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int32)
    assert (unpack_weights_np(pack_weights_np(q, bits), bits, n) == q).all()
