"""Layer-adaptive precision search tests + AOT export regression tests."""

import numpy as np
import pytest

from compile import mixed as mx
from compile import model as qm
from compile.aot import lower_int_graph, to_hlo_text
from compile.dataset import make_dataset
from compile.snn import MlpArch, init_params
from compile.train import train


@pytest.fixture(scope="module")
def trained():
    data = make_dataset(n_train=1024, n_test=256)
    arch = MlpArch(sizes=(256, 64, 10), timesteps=8)
    res = train(arch, data, steps=150, lr=3e-3)
    params_by_bits = {b: res.params for b in (2, 4, 8)}
    return data, arch, params_by_bits


class TestBuildMixed:
    def test_layers_take_requested_widths(self, trained):
        _, arch, pbb = trained
        m = mx.build_mixed(pbb, arch, [8, 4])
        assert [l.bits for l in m.layers] == [8, 4]
        assert m.scheme == "mixed"

    def test_memory_between_uniform_extremes(self, trained):
        _, arch, pbb = trained
        m8 = mx.build_mixed(pbb, arch, [8, 8]).memory_bits()
        m2 = mx.build_mixed(pbb, arch, [2, 2]).memory_bits()
        mixed = mx.build_mixed(pbb, arch, [8, 2]).memory_bits()
        assert m2 < mixed < m8

    def test_mixed_inference_runs(self, trained):
        data, arch, pbb = trained
        m = mx.build_mixed(pbb, arch, [4, 8])
        acc = qm.accuracy_int(m, data.x_test[:128], data.y_test[:128], batch=128)
        assert 0.0 <= acc <= 1.0

    def test_mixed_equals_uniform_when_all_same(self, trained):
        data, arch, pbb = trained
        import jax.numpy as jnp

        uni = qm.quantize_model(pbb[4], arch, 4, "lspine")
        m = mx.build_mixed(pbb, arch, [4, 4])
        x = jnp.asarray(data.x_test[:16])
        np.testing.assert_array_equal(
            np.asarray(qm.forward_int_ref(m, x)),
            np.asarray(qm.forward_int_ref(uni, x)),
        )


class TestGreedySearch:
    def test_search_respects_accuracy_floor(self, trained):
        data, arch, pbb = trained
        res = mx.greedy_mixed_search(
            pbb, arch, data.x_test[:256], data.y_test[:256], epsilon=0.03
        )
        assert res.accuracy >= res.int8_accuracy - 0.03 - 1e-9
        assert len(res.bits_per_layer) == 2
        assert all(b in (2, 4, 8) for b in res.bits_per_layer)

    def test_search_saves_memory_when_budget_allows(self, trained):
        data, arch, pbb = trained
        # huge epsilon -> should demote everything to INT2
        res = mx.greedy_mixed_search(
            pbb, arch, data.x_test[:128], data.y_test[:128], epsilon=1.0
        )
        assert res.bits_per_layer == [2, 2]

    def test_zero_budget_keeps_int8(self, trained):
        data, arch, pbb = trained
        res = mx.greedy_mixed_search(
            pbb, arch, data.x_test[:128], data.y_test[:128], epsilon=-1.0
        )
        assert res.bits_per_layer == [8, 8]


class TestAotRegression:
    def test_hlo_text_never_elides_constants(self, trained):
        """Regression for the print_large_constants bug: the default
        as_hlo_text() replaces big constant arrays with `{...}`, which
        silently corrupts the packed weights after re-parse."""
        _, arch, pbb = trained
        model = qm.quantize_model(pbb[4], arch, 4, "lspine")
        hlo = lower_int_graph(model, 1)
        assert "{...}" not in hlo, "large constants were elided!"
        # and the weights really are inline: a u32 constant tensor exists
        assert "u32[" in hlo

    def test_hlo_output_is_tuple(self, trained):
        _, arch, pbb = trained
        model = qm.quantize_model(pbb[2], arch, 2, "lspine")
        hlo = lower_int_graph(model, 1)
        # lowered with return_tuple=True -> root is a tuple of one s32
        assert "ROOT" in hlo
        assert "(s32[1,10]{1,0}) tuple" in hlo


def test_to_hlo_text_smoke():
    import jax
    import jax.numpy as jnp

    fn = lambda x: (x * 2.0 + 1.0,)
    hlo = to_hlo_text(jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), np.float32)))
    assert "HloModule" in hlo
    assert "f32[4]" in hlo
