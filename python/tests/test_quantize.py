"""Quantization scheme tests: range safety, optimality orderings, folding."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as qz
from compile.kernels.packed import qmin_qmax


def _w(seed=0, shape=(64, 32), scale=0.1):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, scale, size=shape)).astype(np.float32)


@pytest.mark.parametrize("scheme", qz.SCHEMES)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_range_and_dtype(scheme, bits):
    qt = qz.quantize(_w(), bits, scheme)
    lo, hi = qmin_qmax(bits)
    assert qt.q.dtype == np.int32
    assert qt.q.min() >= lo and qt.q.max() <= hi
    assert qt.scale > 0
    assert qt.bits == bits


def test_unknown_scheme_raises():
    with pytest.raises(ValueError):
        qz.quantize(_w(), 8, "nope")


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_lspine_mse_not_worse_than_stbp(bits):
    """The MSE-clipped search can only improve on min-max (same family)."""
    w = _w(seed=4)
    e_ls = np.mean((w - qz.quantize(w, bits, "lspine").dequant()) ** 2)
    e_st = np.mean((w - qz.quantize(w, bits, "stbp").dequant()) ** 2)
    assert e_ls <= e_st + 1e-12


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_admm_improves_on_init(bits):
    w = _w(seed=5)
    e_admm = np.mean((w - qz.quantize(w, bits, "admm").dequant()) ** 2)
    e_st = np.mean((w - qz.quantize(w, bits, "stbp").dequant()) ** 2)
    assert e_admm <= e_st + 1e-12


def test_trunc_power_of_two_scale():
    qt = qz.quantize(_w(seed=6), 4, "trunc")
    log = np.log2(qt.scale)
    assert abs(log - round(log)) < 1e-9


def test_trunc_truncates_toward_zero():
    w = np.array([[0.99, -0.99]], dtype=np.float32)
    qt = qz.quantize(w, 8, "trunc")
    # |q*scale| must not exceed |w| (truncation never rounds away from 0)
    assert (np.abs(qt.dequant()) <= np.abs(w) + 1e-7).all()


def test_zero_tensor_all_schemes():
    w = np.zeros((4, 4), dtype=np.float32)
    for scheme in qz.SCHEMES:
        qt = qz.quantize(w, 2, scheme)
        assert (qt.q == 0).all()


def test_int8_near_lossless():
    w = _w(seed=7)
    for scheme in qz.SCHEMES:
        rel = np.abs(w - qz.quantize(w, 8, scheme).dequant()).max() / np.abs(w).max()
        assert rel < 0.05, scheme


def test_memory_bits_ratio():
    """Packed storage shrinks 4x from INT8 to INT2 (same tensor)."""
    w = _w(shape=(128, 64))
    m8 = qz.quantize(w, 8, "lspine").memory_bits()
    m2 = qz.quantize(w, 2, "lspine").memory_bits()
    assert m8 == 4 * m2


def test_fold_threshold():
    assert qz.fold_threshold(1.0, 0.25) == 4
    assert qz.fold_threshold(1.0, 0.3) == 3
    assert qz.fold_threshold(1.0, 100.0) == 1  # floor at 1


@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 10.0),
)
def test_quantize_property(bits, seed, scale):
    w = _w(seed=seed, shape=(16, 8), scale=scale)
    lo, hi = qmin_qmax(bits)
    for scheme in qz.SCHEMES:
        qt = qz.quantize(w, bits, scheme)
        assert qt.q.min() >= lo and qt.q.max() <= hi
        # dequant error bounded by ~scale (per-element, after clipping the
        # clip region); sanity: MSE is finite and below the raw power.
        err = np.mean((w - qt.dequant()) ** 2)
        assert np.isfinite(err)
