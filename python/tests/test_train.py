"""Training smoke tests: loss decreases, QAT improves low-bit accuracy."""

import numpy as np
import pytest

from compile import model as qm
from compile.dataset import make_dataset
from compile.snn import MlpArch
from compile.train import qat_finetune, train


@pytest.fixture(scope="module")
def small():
    data = make_dataset(n_train=1024, n_test=256)
    arch = MlpArch(sizes=(256, 64, 10), timesteps=8)
    res = train(arch, data, steps=120, lr=3e-3)
    return data, arch, res


def test_loss_decreases(small):
    _, _, res = small
    assert res.loss_curve[-1] < res.loss_curve[0] * 0.5


def test_learns_above_chance(small):
    _, _, res = small
    assert res.test_acc > 0.4  # 10 classes, chance = 0.1


def test_train_acc_at_least_test(small):
    _, _, res = small
    assert res.train_acc >= res.test_acc - 0.05


def test_deterministic(small):
    data, arch, res = small
    res2 = train(arch, data, steps=120, lr=3e-3)
    for a, b in zip(res.params, res2.params):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_qat_improves_int2(small):
    data, arch, res = small
    base = qm.accuracy_int(
        qm.quantize_model(res.params, arch, 2, "lspine"), data.x_test, data.y_test
    )
    tuned_params = qat_finetune(res.params, arch, data, 2, steps=80)
    tuned = qm.accuracy_int(
        qm.quantize_model(tuned_params, arch, 2, "lspine"), data.x_test, data.y_test
    )
    assert tuned >= base


def test_dataset_deterministic():
    a = make_dataset(n_train=64, n_test=32)
    b = make_dataset(n_train=64, n_test=32)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_test, b.y_test)


def test_dataset_range():
    d = make_dataset(n_train=64, n_test=32)
    assert d.x_train.min() >= 0.0 and d.x_train.max() <= 1.0
    assert set(np.unique(d.y_train)) <= set(range(10))
