"""Model graph tests: shapes, kernel-vs-oracle on the full graph, formats."""

import struct

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as qm
from compile.dataset import make_dataset
from compile.snn import ConvArch, MlpArch, forward_float, init_params


@pytest.fixture(scope="module")
def data():
    return make_dataset(n_train=256, n_test=128)


@pytest.fixture(scope="module")
def mlp_model(data):
    arch = MlpArch(sizes=(256, 32, 10), timesteps=8)
    params = init_params(arch, seed=1)
    return qm.quantize_model(params, arch, 4, "lspine"), arch, params


@pytest.fixture(scope="module")
def conv_model():
    arch = ConvArch(timesteps=4)
    params = init_params(arch, seed=2)
    return qm.quantize_model(params, arch, 4, "lspine"), arch, params


class TestShapes:
    def test_mlp_counts_shape(self, mlp_model, data):
        model, arch, _ = mlp_model
        x = jnp.asarray(data.x_test[:8])
        counts = qm.forward_int_ref(model, x)
        assert counts.shape == (8, 10)
        assert counts.dtype == jnp.int32

    def test_conv_counts_shape(self, conv_model, data):
        model, arch, _ = conv_model
        x = jnp.asarray(data.x_test[:4])
        counts = qm.forward_int_ref(model, x)
        assert counts.shape == (4, 10)

    def test_counts_bounded_by_timesteps(self, mlp_model, data):
        model, arch, _ = mlp_model
        counts = np.asarray(qm.forward_int_ref(model, jnp.asarray(data.x_test[:16])))
        assert counts.min() >= 0 and counts.max() <= arch.timesteps


class TestKernelGraph:
    def test_mlp_kernel_equals_ref(self, mlp_model, data):
        model, _, _ = mlp_model
        x = jnp.asarray(data.x_test[:8])
        np.testing.assert_array_equal(
            np.asarray(qm.forward_int(model, x)),
            np.asarray(qm.forward_int_ref(model, x)),
        )

    def test_conv_kernel_equals_ref(self, conv_model, data):
        model, _, _ = conv_model
        x = jnp.asarray(data.x_test[:4])
        np.testing.assert_array_equal(
            np.asarray(qm.forward_int(model, x)),
            np.asarray(qm.forward_int_ref(model, x)),
        )


class TestFloatGraph:
    def test_float_forward_shapes(self, mlp_model, data):
        _, arch, params = mlp_model
        logits = forward_float([jnp.asarray(p) for p in params], arch, jnp.asarray(data.x_test[:8]))
        assert logits.shape == (8, 10)

    def test_conv_float_forward(self, conv_model, data):
        _, arch, params = conv_model
        logits = forward_float([jnp.asarray(p) for p in params], arch, jnp.asarray(data.x_test[:4]))
        assert logits.shape == (4, 10)


class TestQuantModel:
    def test_theta_positive(self, mlp_model):
        model, _, _ = mlp_model
        assert all(l.theta >= 1 for l in model.layers)

    def test_memory_scaling(self, data):
        arch = MlpArch(sizes=(256, 32, 10), timesteps=8)
        params = init_params(arch, seed=1)
        m2 = qm.quantize_model(params, arch, 2, "lspine").memory_bits()
        m8 = qm.quantize_model(params, arch, 8, "lspine").memory_bits()
        assert m8 / m2 == pytest.approx(4.0, rel=0.1)


class TestFormats:
    def test_weights_roundtrip_header(self, tmp_path, mlp_model):
        model, arch, _ = mlp_model
        p = tmp_path / "w.bin"
        qm.write_weights(str(p), model)
        blob = p.read_bytes()
        assert blob[:4] == b"LSPW"
        ver, n_layers, timesteps, leak = struct.unpack_from("<IIII", blob, 4)
        assert (ver, n_layers, timesteps, leak) == (
            qm.FORMAT_VERSION,
            len(model.layers),
            arch.timesteps,
            arch.leak_shift,
        )
        # first layer header
        bits, k, n, nw = struct.unpack_from("<IIII", blob, 20)
        l0 = model.layers[0]
        assert (bits, k, n, nw) == (l0.bits, l0.k_in, l0.n_out, l0.n_words)
        scale, theta = struct.unpack_from("<fi", blob, 36)
        assert scale == pytest.approx(l0.scale)
        assert theta == l0.theta
        # payload size: full file accounted for
        expected = 20 + sum(24 + 4 * l.packed.size for l in model.layers)
        assert len(blob) == expected

    def test_dataset_format(self, tmp_path, data):
        p = tmp_path / "d.bin"
        qm.write_dataset(str(p), data.x_test, data.y_test)
        blob = p.read_bytes()
        assert blob[:4] == b"LSPD"
        ver, n, dim, classes = struct.unpack_from("<IIII", blob, 4)
        assert (n, dim) == (len(data.x_test), data.x_test.shape[1])
        assert classes == 10
        assert len(blob) == 20 + n * dim + n
        # pixel bytes match the u8 encoding contract
        x0 = np.frombuffer(blob[20 : 20 + dim], dtype=np.uint8)
        expected = np.clip(np.round(data.x_test[0] * 255), 0, 255).astype(np.uint8)
        np.testing.assert_array_equal(x0, expected)
