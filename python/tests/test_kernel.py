"""pytest: pallas kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes and bit widths; every case must match the oracle
bit-for-bit (integer dynamics: no tolerance, exact equality).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lif_simd import lif_simd_step
from compile.kernels.packed import pack_weights_np, qmin_qmax
from compile.kernels.ref import encode_step_ref, lif_step_ref


def _case(bits, k, n, b, seed, theta=7, leak_shift=2, v_range=400):
    rng = np.random.default_rng(seed)
    lo, hi = qmin_qmax(bits)
    q = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int32)
    pw = jnp.asarray(pack_weights_np(q, bits))
    s = jnp.asarray(rng.integers(0, 2, size=(b, k)).astype(np.int32))
    v = jnp.asarray(rng.integers(-v_range, v_range, size=(b, n)).astype(np.int32))
    kw = dict(bits=bits, n_out=n, theta=theta, leak_shift=leak_shift)
    o_ref, v_ref = lif_step_ref(s, pw, v, **kw)
    o_k, v_k = lif_simd_step(s, pw, v, **kw)
    np.testing.assert_array_equal(np.asarray(o_k), np.asarray(o_ref))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_ref))
    return np.asarray(o_ref), np.asarray(v_ref)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize(
    "k,n,b", [(1, 1, 1), (9, 8, 4), (64, 10, 32), (256, 128, 128), (37, 23, 5)]
)
def test_kernel_matches_ref(bits, k, n, b):
    _case(bits, k, n, b, seed=bits * 1000 + k * 10 + n + b)


@settings(max_examples=40, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    b=st.integers(1, 32),
    theta=st.integers(1, 100),
    leak_shift=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_property(bits, k, n, b, theta, leak_shift, seed):
    _case(bits, k, n, b, seed, theta=theta, leak_shift=leak_shift)


def test_spikes_are_binary_and_reset_subtracts():
    theta = 5
    o, v = _case(4, 32, 16, 8, seed=1, theta=theta)
    assert set(np.unique(o)) <= {0, 1}
    # reset-by-subtraction: non-firing neurons are strictly below theta
    # (firing ones may stay above if I >> theta — they fire again next step)
    assert (v[o == 0] < theta).all()


def test_zero_spikes_only_leak():
    """No input spikes: V' = V - (V >> k), nothing fires below theta."""
    bits, k, n, b = 8, 6, 4, 3
    pw = jnp.asarray(
        pack_weights_np(np.full((k, n), 7, dtype=np.int32), bits)
    )
    s = jnp.zeros((b, k), jnp.int32)
    v = jnp.asarray(np.array([[8, -8, 3, 0]] * b, dtype=np.int32))
    o, v2 = lif_step_ref(s, pw, v, bits=bits, n_out=n, theta=100, leak_shift=2)
    assert (np.asarray(o) == 0).all()
    # arithmetic shift: 8 - 2 = 6 ; -8 - (-2) = -6 ; 3 - 0 = 3
    np.testing.assert_array_equal(np.asarray(v2)[0], [6, -6, 3, 0])


def test_negative_membrane_arithmetic_shift():
    """-5 >> 2 == -2 (floor), so leak of -5 is -5 - (-2) = -3."""
    pw = jnp.asarray(pack_weights_np(np.zeros((1, 1), np.int32), 8))
    v = jnp.asarray(np.array([[-5]], dtype=np.int32))
    s = jnp.zeros((1, 1), jnp.int32)
    _, v2 = lif_step_ref(s, pw, v, bits=8, n_out=1, theta=10, leak_shift=2)
    assert int(np.asarray(v2)[0, 0]) == -3


def test_theta_exact_boundary_fires():
    """V' == theta must fire (>= comparison, matches the NCE comparator)."""
    q = np.array([[5]], dtype=np.int32)
    pw = jnp.asarray(pack_weights_np(q, 8))
    s = jnp.ones((1, 1), jnp.int32)
    v = jnp.zeros((1, 1), jnp.int32)
    o, v2 = lif_step_ref(s, pw, v, bits=8, n_out=1, theta=5, leak_shift=2)
    assert int(np.asarray(o)[0, 0]) == 1
    assert int(np.asarray(v2)[0, 0]) == 0


class TestEncoder:
    def test_total_spikes(self):
        """After T steps, total spikes == (x_u8 * T) >> 8."""
        x = jnp.asarray(np.arange(256, dtype=np.int32).reshape(1, 256))
        T = 16
        total = sum(np.asarray(encode_step_ref(x, t)) for t in range(T))
        expected = (np.arange(256) * T) >> 8
        np.testing.assert_array_equal(total[0], expected)

    def test_binary_steps(self):
        x = jnp.asarray(np.arange(256, dtype=np.int32).reshape(1, 256))
        for t in range(16):
            s = np.asarray(encode_step_ref(x, t))
            assert set(np.unique(s)) <= {0, 1}

    def test_zero_and_max(self):
        x = jnp.asarray(np.array([[0, 255]], dtype=np.int32))
        total = sum(np.asarray(encode_step_ref(x, t)) for t in range(16))
        assert total[0, 0] == 0
        assert total[0, 1] == (255 * 16) >> 8  # 15 of 16 steps
