# L-SPINE reproduction — top-level targets.
#
# The rust crate is fully hermetic: `make test` needs no python and no
# network. `make artifacts` forges deterministic synthetic artifacts via
# the in-tree generator (lspine::forge); the python author path
# (python/compile) remains the way to produce *trained* artifacts when a
# jax environment is available.

CARGO := cargo

.PHONY: all build test artifacts bench clean

all: build

build:
	cd rust && $(CARGO) build --release

# Tier-1 verify: build + the full hermetic test suite.
test:
	cd rust && $(CARGO) build --release && $(CARGO) test -q

# Forge-backed artifacts (written to rust/artifacts, the path the CLI,
# benches and examples resolve when run from rust/).
artifacts:
	cd rust && $(CARGO) run --release -- forge --out artifacts

# Hermetic benches; both print BENCH_JSON lines for trajectory tracking.
bench:
	cd rust && $(CARGO) bench --bench hotpath
	cd rust && $(CARGO) bench --bench ablation

clean:
	cd rust && $(CARGO) clean
	rm -rf rust/artifacts
