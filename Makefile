# L-SPINE reproduction — top-level targets.
#
# The rust crate is fully hermetic: `make test` needs no python and no
# network. `make artifacts` forges deterministic synthetic artifacts via
# the in-tree generator (lspine::forge); the python author path
# (python/compile) remains the way to produce *trained* artifacts when a
# jax environment is available.

CARGO := cargo

.PHONY: all build test artifacts bench bench-json bench-smoke stream-smoke loadgen-smoke prune-smoke chaos-smoke swap-smoke ttfs-smoke doc clean

all: build

build:
	cd rust && $(CARGO) build --release

# Tier-1 verify: build + the full hermetic test suite.
test:
	cd rust && $(CARGO) build --release && $(CARGO) test -q

# Forge-backed artifacts (written to rust/artifacts, the path the CLI,
# benches and examples resolve when run from rust/).
artifacts:
	cd rust && $(CARGO) run --release -- forge --out artifacts

# Hermetic benches; both print BENCH_JSON lines for trajectory tracking.
bench:
	cd rust && $(CARGO) bench --bench hotpath
	cd rust && $(CARGO) bench --bench ablation

# Run both benches and collect their BENCH_JSON lines into the
# trajectory files at the repo root (one JSON object per line).
# Compare two runs with: tools/bench_diff.py OLD.json BENCH_hotpath.json
# (fails on a >15% msynops_per_s regression; entries key on
# suite/name/backend so kernel-backend sweeps diff like-for-like).
#
# BENCH_hotpath.json / BENCH_ablation.json are CHECKED IN as the perf
# baselines the CI bench-smoke job diffs against at a loose 50%
# threshold (catastrophic-collapse net; zero-valued seed entries never
# gate). Refresh them from a bench-smoke CI artifact — same runner
# class — not from dev hardware. The precise 15% gate is the bench-gate
# CI job, which benches the PR head and its merge-base on one runner.
# (plain redirects, not `| tee`, so a failing bench fails the target)
bench-json:
	cd rust && $(CARGO) bench --bench hotpath > ../.bench_hotpath.out || (cat ../.bench_hotpath.out; exit 1)
	cat .bench_hotpath.out
	sed -n 's/^BENCH_JSON //p' .bench_hotpath.out > BENCH_hotpath.json
	rm -f .bench_hotpath.out
	cd rust && $(CARGO) bench --bench ablation > ../.bench_ablation.out || (cat ../.bench_ablation.out; exit 1)
	cat .bench_ablation.out
	sed -n 's/^BENCH_JSON //p' .bench_ablation.out > BENCH_ablation.json
	rm -f .bench_ablation.out
	@echo "wrote BENCH_hotpath.json + BENCH_ablation.json"

# CI smoke: single-iteration benches, still emitting every BENCH_JSON line.
bench-smoke:
	$(MAKE) bench-json LSPINE_BENCH_ITERS=1

# Streaming end-to-end smoke: forge artifacts (stream.lsps included),
# replay the stream through stateful sessions on 2 workers, and assert
# the windows actually produced output spikes (nonzero predictions).
stream-smoke:
	cd rust && $(CARGO) run --release -- forge --out artifacts
	cd rust && $(CARGO) run --release -- stream --model mlp --bits 4 --steps 4 --workers 2 > ../.stream_smoke.out || (cat ../.stream_smoke.out; exit 1)
	cat .stream_smoke.out
	grep -Eq "nonzero_windows=[1-9][0-9]*" .stream_smoke.out
	rm -f .stream_smoke.out

# Network end-to-end smoke: boot a real TCP front end, drive 8 concurrent
# streaming sessions through the open-loop loadgen client, assert every
# window got a reply (ok>0, zero protocol errors), then drain the server
# over the wire (--drain sends the Drain frame; the serve process exits
# on its own once the front end finishes flushing).
loadgen-smoke:
	cd rust && $(CARGO) build --release
	cd rust && $(CARGO) run --release -- forge --out artifacts
	cd rust && \
	( ./target/release/lspine serve --backend native --listen 127.0.0.1:17317 --workers 2 > ../.loadgen_serve.out 2>&1 & ) && \
	./target/release/lspine loadgen --connect 127.0.0.1:17317 --sessions 8 --windows 4 --drain --retry-secs 20 > ../.loadgen_smoke.out || (cat ../.loadgen_smoke.out ../.loadgen_serve.out; exit 1)
	cat .loadgen_smoke.out
	grep -Eq "ok=[1-9]" .loadgen_smoke.out
	grep -Eq "protocol_errors=0" .loadgen_smoke.out
	grep -Eq "lost=0" .loadgen_smoke.out
	cat .loadgen_serve.out
	rm -f .loadgen_smoke.out .loadgen_serve.out

# Sparse end-to-end smoke: forge 0.9-magnitude-pruned artifacts (sparse
# LSPW v2 rows on disk), serve them over TCP, drive one loadgen pass
# through the skip-walk engine, and assert every request got a typed
# answer (ok>0, zero lost, zero protocol errors). Separate artifacts
# dir + port so it composes with loadgen-smoke in one CI job.
prune-smoke:
	cd rust && $(CARGO) build --release
	cd rust && $(CARGO) run --release -- forge --out artifacts-sparse --sparsity 0.9
	cd rust && \
	( ./target/release/lspine serve --backend native --artifacts artifacts-sparse --listen 127.0.0.1:17319 --workers 2 > ../.prune_serve.out 2>&1 & ) && \
	./target/release/lspine loadgen --connect 127.0.0.1:17319 --sessions 8 --windows 4 --drain --retry-secs 20 > ../.prune_smoke.out || (cat ../.prune_smoke.out ../.prune_serve.out; exit 1)
	cat .prune_smoke.out
	grep -Eq "ok=[1-9]" .prune_smoke.out
	grep -Eq "protocol_errors=0" .prune_smoke.out
	grep -Eq "lost=0" .prune_smoke.out
	cat .prune_serve.out
	rm -f .prune_smoke.out .prune_serve.out

# Fault-tolerance end-to-end smoke: serve with a seeded fault plan
# (worker panic, a 100ms stall, one dropped reply), drive the loadgen
# client with retry+backoff against it, and assert the contract held:
# every request resolved (lost=0, zero protocol errors) AND the server
# really did panic and restart (panics/restarts nonzero in its summary).
# Separate port so it composes with the other smokes in one CI job.
chaos-smoke:
	cd rust && $(CARGO) build --release
	cd rust && $(CARGO) run --release -- forge --out artifacts
	cd rust && \
	( ./target/release/lspine serve --backend native --listen 127.0.0.1:17321 --workers 2 --faults "panic@6,stall@12:100ms,drop@18" > ../.chaos_serve.out 2>&1 & ) && \
	./target/release/lspine loadgen --connect 127.0.0.1:17321 --sessions 8 --windows 4 --retries 3 --backoff-ms 20 --drain --retry-secs 20 > ../.chaos_smoke.out || (cat ../.chaos_smoke.out ../.chaos_serve.out; exit 1)
	cat .chaos_smoke.out
	grep -Eq "lost=0" .chaos_smoke.out
	grep -Eq "protocol_errors=0" .chaos_smoke.out
	# the drained server may still be flushing its final summary line
	for i in $$(seq 1 50); do grep -q "restarts=" .chaos_serve.out && break; sleep 0.2; done
	cat .chaos_serve.out
	grep -Eq "panics=[1-9]" .chaos_serve.out
	grep -Eq "restarts=[1-9]" .chaos_serve.out
	rm -f .chaos_smoke.out .chaos_serve.out

# Early-exit (TTFS) end-to-end smoke: serve over TCP, drive early-exit
# streaming windows (version-4 frames) through the loadgen client with
# the one-spike-per-pixel TTFS coding, and assert the decision contract
# held on every reply: nothing lost, no protocol errors, and every
# decision step inside the requested budget (decision_viol=0 — the
# client checks 1 <= decision_step <= steps on each WindowEx frame).
# Separate port so it composes with the other smokes in one CI job.
ttfs-smoke:
	cd rust && $(CARGO) build --release
	cd rust && $(CARGO) run --release -- forge --out artifacts
	cd rust && \
	( ./target/release/lspine serve --backend native --listen 127.0.0.1:17325 --workers 2 > ../.ttfs_serve.out 2>&1 & ) && \
	./target/release/lspine loadgen --connect 127.0.0.1:17325 --sessions 8 --windows 4 --steps 8 --encoder ttfs:16 --early-exit --drain --retry-secs 20 > ../.ttfs_smoke.out || (cat ../.ttfs_smoke.out ../.ttfs_serve.out; exit 1)
	cat .ttfs_smoke.out
	grep -Eq "ok=[1-9]" .ttfs_smoke.out
	grep -Eq "protocol_errors=0" .ttfs_smoke.out
	grep -Eq "lost=0" .ttfs_smoke.out
	grep -Eq "decision_viol=0" .ttfs_smoke.out
	grep -Eq "decision_p50=[1-9]" .ttfs_smoke.out
	cat .ttfs_serve.out
	rm -f .ttfs_smoke.out .ttfs_serve.out

# Hot-swap end-to-end smoke: serve BOTH forged models from the
# multi-tenant registry, drive mixed loadgen traffic at them, hot-swap
# the mlp model mid-run over the admin surface, then drain. Asserts
# zero-downtime (lost=0, protocol_errors=0), that both models actually
# answered windows (per-model summary keys), and that the swap really
# republished (version bumped in the admin output). Separate port so it
# composes with the other smokes in one CI job.
swap-smoke:
	cd rust && $(CARGO) build --release
	cd rust && $(CARGO) run --release -- forge --out artifacts
	cd rust && \
	( ./target/release/lspine serve --backend native --models artifacts --model mlp --listen 127.0.0.1:17323 --workers 2 > ../.swap_serve.out 2>&1 & ) && \
	( { ./target/release/lspine loadgen --connect 127.0.0.1:17323 --model mlp,convnet --sessions 8 --windows 40 --rate 10 --retries 3 --backoff-ms 20 --retry-secs 20 > ../.swap_smoke.out 2>&1; echo $$? > ../.swap_loadgen.rc; } & ) && \
	sleep 3 && \
	./target/release/lspine admin --connect 127.0.0.1:17323 --swap mlp > ../.swap_admin.out || (cat ../.swap_admin.out ../.swap_serve.out; exit 1)
	# wait for the loadgen run to finish, then fail on its exit code
	for i in $$(seq 1 150); do test -f .swap_loadgen.rc && break; sleep 0.2; done
	test -f .swap_loadgen.rc && test "$$(cat .swap_loadgen.rc)" = "0" || (cat .swap_smoke.out .swap_serve.out; exit 1)
	cd rust && ./target/release/lspine admin --connect 127.0.0.1:17323 --drain > ../.swap_drain.out || (cat ../.swap_drain.out ../.swap_serve.out; exit 1)
	cat .swap_smoke.out .swap_admin.out
	grep -Eq "mlp_ok=[1-9]" .swap_smoke.out
	grep -Eq "convnet_ok=[1-9]" .swap_smoke.out
	grep -Eq "lost=0" .swap_smoke.out
	grep -Eq "protocol_errors=0" .swap_smoke.out
	grep -Eq "swapped model=mlp version=[0-9]+" .swap_admin.out
	# the drained server may still be flushing its per-model table
	for i in $$(seq 1 50); do grep -q "requests=" .swap_serve.out && break; sleep 0.2; done
	cat .swap_serve.out
	rm -f .swap_smoke.out .swap_admin.out .swap_drain.out .swap_serve.out .swap_loadgen.rc

# The documented-API gate, same flags as the CI docs job.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --lib --document-private-items

clean:
	cd rust && $(CARGO) clean
	rm -rf rust/artifacts rust/artifacts-sparse
