#!/usr/bin/env python3
"""Golden-vector generator for rust/tests/conformance.rs.

Replicates, bit-for-bit, the parts of the rust crate that feed the golden
conformance vectors:

- ``lspine::util::rng::Rng``          (xorshift64*, integer-only)
- ``lspine::forge::layer_seed``       (FNV-1a mix, integer-only)
- ``lspine::forge::raw_network``      (integer-only)
- ``lspine::forge::pixels``           (integer-only)
- ``lspine::forge::float_weights``    (IEEE f64 chain + f64->f32 rounding)
- ``lspine::forge::theta_fp``         (f32, sqrt is IEEE-exact)
- ``lspine::quant::schemes``          (f32 emulated with np.float32; all
  f64 accumulations are sequential Python-float loops matching the rust
  fold order; rounding is round-half-away-from-zero, computed exactly)
- ``lspine::model::SnnEngine``        (integer-only: rate encoder, LIF,
  im2col / maxpool-OR conv path)

Cross-language float safety: every arithmetic step is either exact
integer math, an IEEE-deterministic f32/f64 + - * / sqrt, or guarded —
the one libm call on the rust side (log2/powf in the trunc quantizer) is
reproduced via exact frexp arithmetic and the script *verifies* the
input sits far from a rounding boundary, so any correctly-rounded-ish
libm agrees.

Usage:  python3 tools/gen_goldens.py   (writes rust/tests/golden/*.json)
"""

import json
import math
import os
import sys

import numpy as np

MASK = (1 << 64) - 1
f32 = np.float32

# --------------------------------------------------------------------
# util::rng::Rng (xorshift64*)
# --------------------------------------------------------------------


class Rng:
    def __init__(self, seed):
        self.state = max(seed, 1) & MASK

    def next_u64(self):
        s = self.state
        s ^= (s << 13) & MASK
        s ^= s >> 7
        s ^= (s << 17) & MASK
        self.state = s
        return (s * 0x2545F4914F6CDD1D) & MASK

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def range_i64(self, lo, hi):
        return lo + self.below(hi - lo + 1)

    def f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)


# --------------------------------------------------------------------
# forge generators
# --------------------------------------------------------------------

FNV_PRIME = 0x00000100000001B3
GOLDEN_SEED = 0x600D5EED
WEIGHT_AMP = 0.25


def layer_seed(seed, tag, layer):
    h = 0xCBF29CE484222325
    for b in tag.encode():
        h ^= b
        h = (h * FNV_PRIME) & MASK
    h ^= seed
    h = (h * FNV_PRIME) & MASK
    h ^= (layer + 0x9E3779B97F4A7C15) & MASK
    return (h * FNV_PRIME) & MASK


def pixels(seed, n, dim):
    rng = Rng(layer_seed(seed, "pixels", 0))
    return [rng.below(256) for _ in range(n * dim)]


def float_weights(seed, length):
    rng = Rng(seed)
    out = np.empty(length, dtype=np.float32)
    for i in range(length):
        out[i] = f32((rng.f64() * 2.0 - 1.0) * WEIGHT_AMP)
    return out


def theta_fp(k_in):
    return (f32(0.5) * f32(WEIGHT_AMP)) * np.sqrt(f32(k_in))


def qrange(bits):
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def raw_layer_q(seed, layer, bits, k, n):
    rng = Rng(layer_seed(seed, "raw", layer) ^ bits)
    lo, hi = qrange(bits)
    return np.array(
        [[rng.range_i64(lo, hi) for _ in range(n)] for _ in range(k)], dtype=np.int64
    )


# --------------------------------------------------------------------
# quant::schemes (f32 emulation of the rust implementations)
# --------------------------------------------------------------------


def round_half_away(v32):
    """Rust f32::round of a float32 value, computed exactly."""
    x = float(v32)  # exact f32 -> f64
    r = math.floor(abs(x) + 0.5)  # exact: f32 + 0.5 in f64 is exact
    return -r if x < 0 else r


def quantize_with_scale(w32, scale32, bits):
    lo, hi = qrange(bits)
    v = w32 / scale32  # float32 IEEE division (array / scalar)
    return np.array(
        [min(max(round_half_away(x), lo), hi) for x in v], dtype=np.int64
    )


def amax32(w32):
    return f32(np.max(np.abs(w32))) if len(w32) else f32(0.0)


def quantize_stbp(w32, bits):
    _, hi = qrange(bits)
    a = amax32(w32)
    scale = a / f32(hi) if float(a) > 0.0 else f32(1.0)
    return quantize_with_scale(w32, scale, bits), scale


def quantize_lspine(w32, bits):
    GRID = 80
    _, hi = qrange(bits)
    a = amax32(w32)
    if float(a) == 0.0:
        return np.zeros(len(w32), dtype=np.int64), f32(1.0)
    best = None
    for i in range(1, GRID + 1):
        scale = (a * (f32(i) / f32(GRID))) / f32(hi)
        q = quantize_with_scale(w32, scale, bits)
        err = 0.0
        s64 = float(scale)
        for wf, qv in zip(w32, q):  # sequential f64 fold, rust order
            e = float(wf) - float(qv) * s64
            err += e * e
        err /= len(w32)
        if best is None or err < best[2]:
            best = (q, scale, err)
    return best[0], best[1]


def quantize_admm(w32, bits):
    ITERS = 12
    _, hi = qrange(bits)
    a = amax32(w32)
    scale = a / f32(hi) if float(a) > 0.0 else f32(1.0)
    q = quantize_with_scale(w32, scale, bits)
    for _ in range(ITERS):
        denom = 0.0
        for v in q:
            denom += float(v) * float(v)
        if denom == 0.0:
            break
        num = 0.0
        for wf, qv in zip(w32, q):
            num += float(wf) * float(qv)
        s_new = f32(num / denom)
        if float(s_new) <= 0.0:
            scale = a / f32(hi) if float(a) > 0.0 else f32(1.0)
            break
        scale = s_new
        q_next = quantize_with_scale(w32, scale, bits)
        if np.array_equal(q_next, q):
            break
        q = q_next
    return q, scale


def quantize_trunc(w32, bits):
    lo, hi = qrange(bits)
    a = amax32(w32)
    if float(a) == 0.0:
        return np.zeros(len(w32), dtype=np.int64), f32(1.0)
    x = a / f32(hi)  # exactly rust's (amax / hi as f32)
    # e = ceil(log2(x)), computed exactly via frexp: x = m * 2^E, m in [0.5,1)
    m, E = math.frexp(float(x))
    e = E - 1 if m == 0.5 else E
    # Guard: rust computes ceil(x.log2()) through libm log2f. Verify the
    # true log2 sits far from the integer boundary so any sane libm agrees.
    t = math.log2(float(x))
    frac = abs(t - round(t))
    if m != 0.5 and frac < 1e-3:
        raise SystemExit(
            f"trunc scale boundary hazard: log2({float(x)}) = {t}; pick a new seed"
        )
    scale = f32(2.0**e)  # exact power of two
    v = w32 / scale
    q = np.array(
        [min(max(math.trunc(float(x_)), lo), hi) for x_ in v], dtype=np.int64
    )
    return q, scale


QUANTIZERS = {
    "lspine": quantize_lspine,
    "stbp": quantize_stbp,
    "admm": quantize_admm,
    "trunc": quantize_trunc,
}


def fold_threshold(theta32, scale32):
    return max(1, int(round_half_away(theta32 / scale32)))


# --------------------------------------------------------------------
# model::SnnEngine (integer semantics)
# --------------------------------------------------------------------


def spike_step(pixels_arr, t):
    x = pixels_arr
    return ((x * (t + 1)) >> 8) - ((x * t) >> 8)


def lif_rows(spikes_in, w, v, theta, leak=2):
    """One timestep of a LIF row bank. spikes_in [k] 0/1, w [k,n], v [n]."""
    if spikes_in.any():
        acc = w[spikes_in != 0].sum(axis=0)
    else:
        acc = np.zeros(w.shape[1], dtype=np.int64)
    v2 = v - (v >> leak) + acc
    fired = (v2 >= theta).astype(np.int64)
    v2 = v2 - fired * theta
    return fired, v2


def infer_mlp_window(sizes, layers, pix, steps, vs, leak=2):
    """One streaming window: `steps` timesteps over persistent membranes
    `vs`, window-local encoder phase (each window encodes from t=0, like
    ``SnnEngine::infer_window``). Returns this window's counts."""
    counts = np.zeros(sizes[-1], dtype=np.int64)
    px = np.array(pix, dtype=np.int64)
    for t in range(steps):
        spk = spike_step(px, t)
        for i, (w, theta) in enumerate(layers):
            spk, vs[i] = lif_rows(spk, w, vs[i], theta, leak)
        counts += spk
    return counts


def infer_mlp(sizes, layers, pix, T, leak=2):
    """layers: [(w [k,n] int64, theta int)]. Returns per-class counts."""
    vs = [np.zeros(n, dtype=np.int64) for n in sizes[1:]]
    return infer_mlp_window(sizes, layers, pix, T, vs, leak)


def im2col_table(side, ch):
    row_k = 9 * ch
    table = np.full(side * side * row_k, -1, dtype=np.int64)
    for y in range(side):
        for x in range(side):
            base = (y * side + x) * row_k
            for c in range(ch):
                for ky in range(3):
                    sy = y + ky - 1
                    for kx in range(3):
                        sx = x + kx - 1
                        if 0 <= sy < side and 0 <= sx < side:
                            table[base + c * 9 + ky * 3 + kx] = (
                                sy * side + sx
                            ) * ch + c
    return table


def gather(plane, table):
    out = np.zeros(len(table), dtype=np.int64)
    valid = table >= 0
    out[valid] = plane[table[valid]]
    return out


def maxpool2(plane, side, ch):
    p = plane.reshape(side, side, ch)
    half = side // 2
    out = np.zeros((half, half, ch), dtype=np.int64)
    for y in range(half):
        for x in range(half):
            out[y, x] = np.max(
                p[2 * y : 2 * y + 2, 2 * x : 2 * x + 2].reshape(4, ch), axis=0
            )
    return out.reshape(-1)


def infer_conv(side, channels, classes, layers, pix, T, leak=2):
    c0, c1, c2 = channels
    s2, s4 = side // 2, side // 4
    t0, t1 = im2col_table(side, c0), im2col_table(s2, c1)
    v0 = np.zeros((side * side, c1), dtype=np.int64)
    v1 = np.zeros((s2 * s2, c2), dtype=np.int64)
    v2 = np.zeros(classes, dtype=np.int64)
    counts = np.zeros(classes, dtype=np.int64)
    px = np.array(pix, dtype=np.int64)
    (w0, th0), (w1, th1), (w2, th2) = layers
    for t in range(T):
        in_plane = spike_step(px, t)
        # conv1 (positions x 9*c0) @ (9*c0 x c1)
        patches = gather(in_plane, t0).reshape(side * side, 9 * c0)
        acc = patches @ w0
        vv = v0 - (v0 >> leak) + acc
        fired = (vv >= th0).astype(np.int64)
        v0 = vv - fired * th0
        plane1 = fired.reshape(-1)  # [side,side,c1] channel-last flattened
        pooled1 = maxpool2(plane1, side, c1)
        # conv2
        patches2 = gather(pooled1, t1).reshape(s2 * s2, 9 * c1)
        acc2 = patches2 @ w1
        vv = v1 - (v1 >> leak) + acc2
        fired = (vv >= th1).astype(np.int64)
        v1 = vv - fired * th1
        plane2 = fired.reshape(-1)
        pooled2 = maxpool2(plane2, s2, c2)  # [s4,s4,c2] flattened
        # fc
        spk, v2 = lif_rows(pooled2, w2, v2, th2, leak)
        counts += spk
    return counts


# --------------------------------------------------------------------
# encoder zoo (mirrors rust/src/encode/{ttfs,population}.rs)
# --------------------------------------------------------------------


def ttfs_fire_steps(px, t_steps):
    """``TtfsEncoder::fire_step`` per pixel: the single step each pixel
    fires at, or -1 for x == 0 (never fires)."""
    out = np.empty(len(px), dtype=np.int64)
    for j, x in enumerate(px):
        if x == 0:
            out[j] = -1
        else:
            slot = (int(x) * t_steps) >> 8
            out[j] = t_steps - 1 - min(slot, t_steps - 1)
    return out


def pop_act_table(groups):
    """``PopulationEncoder`` activation lookup: [256, groups] int64."""
    w = max(255 // (groups - 1), 1)
    two_w2 = 2 * w * w
    act = np.zeros((256, groups), dtype=np.int64)
    for x in range(256):
        for i in range(groups):
            c = i * 255 // (groups - 1)
            d = abs(x - c)
            fall = d * d * 255 // two_w2
            act[x, i] = max(255 - fall, 0)  # u32 saturating_sub
    return act


def make_encoder(kind, px, t_budget, groups):
    """Return ``enc(t) -> int64[input_dim]`` matching the rust encoders.

    ``px`` is the *raw* pixel payload: full input_dim for rate/ttfs,
    input_dim // groups for population (group-major expansion)."""
    if kind == "rate":
        arr = np.array(px, dtype=np.int64)
        return lambda t: spike_step(arr, t)
    if kind == "ttfs":
        fire = ttfs_fire_steps(px, t_budget)
        return lambda t: (fire == t).astype(np.int64)
    if kind == "population":
        act = pop_act_table(groups)
        # group-major: pixel p's neurons occupy [p*groups, (p+1)*groups)
        acts = act[np.array(px, dtype=np.int64)].reshape(-1)
        return lambda t: spike_step(acts, t)
    raise ValueError(kind)


# --------------------------------------------------------------------
# early-exit inference (mirrors SnnEngine::run_window(early_exit=true))
# --------------------------------------------------------------------


def early_exit_mlp(sizes, layers, enc, T, leak=2):
    """Fresh-membrane run that stops after the first step with any
    readout spike. Returns (counts, decision_step); decision_step == T
    when the readout stays silent."""
    vs = [np.zeros(n, dtype=np.int64) for n in sizes[1:]]
    counts = np.zeros(sizes[-1], dtype=np.int64)
    for t in range(T):
        spk = enc(t)
        for i, (w, theta) in enumerate(layers):
            spk, vs[i] = lif_rows(spk, w, vs[i], theta, leak)
        counts += spk
        if spk.any():
            return counts, t + 1
    return counts, T


def early_exit_conv(side, channels, classes, layers, enc, T, leak=2):
    """Early-exit twin of ``infer_conv``: stop at the first fc fire."""
    c0, c1, c2 = channels
    s2 = side // 2
    t0, t1 = im2col_table(side, c0), im2col_table(s2, c1)
    v0 = np.zeros((side * side, c1), dtype=np.int64)
    v1 = np.zeros((s2 * s2, c2), dtype=np.int64)
    v2 = np.zeros(classes, dtype=np.int64)
    counts = np.zeros(classes, dtype=np.int64)
    (w0, th0), (w1, th1), (w2, th2) = layers
    for t in range(T):
        in_plane = enc(t)
        patches = gather(in_plane, t0).reshape(side * side, 9 * c0)
        vv = v0 - (v0 >> leak) + patches @ w0
        fired = (vv >= th0).astype(np.int64)
        v0 = vv - fired * th0
        pooled1 = maxpool2(fired.reshape(-1), side, c1)
        patches2 = gather(pooled1, t1).reshape(s2 * s2, 9 * c1)
        vv = v1 - (v1 >> leak) + patches2 @ w1
        fired = (vv >= th1).astype(np.int64)
        v1 = vv - fired * th1
        pooled2 = maxpool2(fired.reshape(-1), s2, c2)
        spk, v2 = lif_rows(pooled2, w2, v2, th2, leak)
        counts += spk
        if spk.any():
            return counts, t + 1
    return counts, T


# --------------------------------------------------------------------
# forge stream families (mirrors rust/src/forge/stream.rs)
# --------------------------------------------------------------------


def beat_amp(phase, period):
    if phase == 0:
        return 40
    if phase == 1:
        return 160
    if phase == 2:
        return 80
    if phase == 3:
        return 20
    t_center = 2 * period // 5
    d = abs(phase - t_center)
    return 48 - 12 * d if d <= 3 else 0


def ecg_stream(seed, windows, window, dim, classes):
    rng = Rng(layer_seed(seed, "stream", 0))
    gains = [96 + rng.below(128) for _ in range(dim)]
    pixels, labels = [], []
    phase = 0
    period = 18 + rng.below(7)
    for _ in range(windows):
        label = rng.below(classes)
        labels.append(label)
        for _ in range(window):
            amp = beat_amp(phase, period)
            for c in range(dim):
                noise = rng.below(13) - 6
                x = 32 + ((amp * gains[c]) >> 8) + noise
                if label > 0 and c % classes == label:
                    x += 24 + 8 * label
                pixels.append(min(max(x, 0), 255))
            phase += 1
            if phase >= period:
                phase = 0
                period = 18 + rng.below(7)
    return pixels, labels


def kws_envelope(frame, onset, window):
    if frame < onset:
        return 0
    dt = frame - onset
    sustain = max(window // 3, 1)
    if dt == 0:
        return 96
    if dt == 1:
        return 200
    if dt < 2 + sustain:
        return 160
    return max(160 - 32 * (dt - 1 - sustain), 0)  # u32 saturating_sub


def kws_stream(seed, windows, window, dim, classes):
    rng = Rng(layer_seed(seed, "kws", 0))
    gains = [128 + rng.below(128) for _ in range(dim)]
    pixels, labels = [], []
    for _ in range(windows):
        label = rng.below(classes)
        labels.append(label)
        onset = rng.below(max(window // 2, 1))
        for f in range(window):
            env = kws_envelope(f, onset, window)
            for c in range(dim):
                noise = rng.below(9) - 4
                x = 20 + noise
                if label > 0 and c % classes == label:
                    x += (env * gains[c]) >> 8
                pixels.append(min(max(x, 0), 255))
    return pixels, labels


def triangle(t, period):
    ph = t % period
    half = period // 2
    if ph <= half:
        return 128 * ph // max(half, 1)
    return 128 * (period - ph) // max(period - half, 1)


def vib_stream(seed, windows, window, dim, classes):
    rng = Rng(layer_seed(seed, "vib", 0))
    period = 8
    phases = [rng.below(period) for _ in range(dim)]
    gains = [96 + rng.below(96) for _ in range(dim)]
    pixels, labels = [], []
    t = 0
    for _ in range(windows):
        label = rng.below(classes)
        labels.append(label)
        for _ in range(window):
            for c in range(dim):
                tri = triangle(t + phases[c], period)
                noise = rng.below(7) - 3
                x = 24 + ((tri * gains[c]) >> 8) + noise
                if label > 0 and c % classes == label and t % 2 == 0:
                    x += 40 + 6 * label
                pixels.append(min(max(x, 0), 255))
            t += 1
    return pixels, labels


# --------------------------------------------------------------------
# golden generation
# --------------------------------------------------------------------

FNV_OFFSET = 0xCBF29CE484222325


def fnv1a64(data):
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK
    return h


def q_fnv(q):
    data = bytearray()
    for v in q:
        data += int(v).to_bytes(4, "little", signed=True)
    return fnv1a64(data)


GOLDEN_THETA = {2: 4, 4: 12, 8: 80}
MLP_SIZES = [24, 16, 10]
CONV = dict(side=8, channels=[1, 3, 5], classes=10)
T = 8
SAMPLES = 4


def conv_shapes(side, channels, classes):
    c0, c1, c2 = channels
    fc_in = (side // 4) * (side // 4) * c2
    return [(9 * c0, c1), (9 * c1, c2), (fc_in, classes)]


def gen_engine_golden():
    out = {}
    # mlp
    dim = MLP_SIZES[0]
    pix = pixels(GOLDEN_SEED, SAMPLES, dim)
    shapes = list(zip(MLP_SIZES[:-1], MLP_SIZES[1:]))
    per_prec = {}
    for bits in (2, 4, 8):
        theta = GOLDEN_THETA[bits]
        layers = [
            (raw_layer_q(GOLDEN_SEED, i, bits, k, n), theta)
            for i, (k, n) in enumerate(shapes)
        ]
        rows = []
        for s in range(SAMPLES):
            counts = infer_mlp(MLP_SIZES, layers, pix[s * dim : (s + 1) * dim], T)
            rows.append([int(c) for c in counts])
        per_prec[f"int{bits}"] = rows
    out["mlp"] = per_prec
    # convnet
    side, channels, classes = CONV["side"], CONV["channels"], CONV["classes"]
    dim = side * side * channels[0]
    pix = pixels(GOLDEN_SEED, SAMPLES, dim)
    shapes = conv_shapes(side, channels, classes)
    per_prec = {}
    for bits in (2, 4, 8):
        theta = GOLDEN_THETA[bits]
        layers = [
            (raw_layer_q(GOLDEN_SEED, i, bits, k, n), theta)
            for i, (k, n) in enumerate(shapes)
        ]
        rows = []
        for s in range(SAMPLES):
            counts = infer_conv(
                side, channels, classes, layers, pix[s * dim : (s + 1) * dim], T
            )
            rows.append([int(c) for c in counts])
        per_prec[f"int{bits}"] = rows
    out["convnet"] = per_prec
    return out


def gen_quant_golden():
    """Scheme x precision pins on the goldenq MLP ([24,16,10], tag goldenq)."""
    shapes = list(zip(MLP_SIZES[:-1], MLP_SIZES[1:]))
    dim = MLP_SIZES[0]
    pix = pixels(GOLDEN_SEED, 2, dim)
    out = {}
    for scheme, quantizer in QUANTIZERS.items():
        per_prec = {}
        for bits in (2, 4, 8):
            layer_recs = []
            engine_layers = []
            for i, (k, n) in enumerate(shapes):
                w = float_weights(layer_seed(GOLDEN_SEED, "goldenq", i), k * n)
                q, scale = quantizer(w, bits)
                theta = fold_threshold(theta_fp(k), scale)
                layer_recs.append(
                    {
                        "q_fnv": f"{q_fnv(q):016x}",
                        "scale_bits": int(np.float32(scale).view(np.uint32)),
                        "theta": theta,
                    }
                )
                engine_layers.append((q.reshape(k, n), theta))
            rows = []
            for s in range(2):
                counts = infer_mlp(
                    MLP_SIZES, engine_layers, pix[s * dim : (s + 1) * dim], T
                )
                rows.append([int(c) for c in counts])
            per_prec[f"int{bits}"] = {"layers": layer_recs, "counts": rows}
        out[scheme] = per_prec
    return out


POP_GROUPS = 4
ENCODERS = ("rate", "ttfs", "population")
STREAM_KNOBS = dict(windows=6, window=8, dim=16, classes=10)


def gen_early_exit_golden():
    """``SnnEngine::infer_until_decision_with_encoder`` pins: for every
    golden arch x encoder x precision x sample, ``[prediction,
    decision_step]`` of a fresh-membrane early-exit run over the T=8
    window (population feeds ``input_dim // POP_GROUPS`` raw pixels;
    decision_step == T when the readout never fires)."""
    out = {}
    arch_runs = [
        ("mlp", MLP_SIZES[0], list(zip(MLP_SIZES[:-1], MLP_SIZES[1:])), None),
        (
            "convnet",
            CONV["side"] * CONV["side"] * CONV["channels"][0],
            conv_shapes(CONV["side"], CONV["channels"], CONV["classes"]),
            CONV,
        ),
    ]
    early_exits = 0
    for model, dim, shapes, conv in arch_runs:
        per_enc = {}
        for kind in ENCODERS:
            raw_dim = dim // POP_GROUPS if kind == "population" else dim
            pix = pixels(GOLDEN_SEED, SAMPLES, raw_dim)
            per_prec = {}
            for bits in (2, 4, 8):
                theta = GOLDEN_THETA[bits]
                layers = [
                    (raw_layer_q(GOLDEN_SEED, i, bits, k, n), theta)
                    for i, (k, n) in enumerate(shapes)
                ]
                rows = []
                for s in range(SAMPLES):
                    px = pix[s * raw_dim : (s + 1) * raw_dim]
                    enc = make_encoder(kind, px, T, POP_GROUPS)
                    if conv is None:
                        counts, step = early_exit_mlp(MLP_SIZES, layers, enc, T)
                    else:
                        counts, step = early_exit_conv(
                            conv["side"],
                            conv["channels"],
                            conv["classes"],
                            layers,
                            enc,
                            T,
                        )
                    early_exits += T - step
                    rows.append([int(np.argmax(counts)), int(step)])
                per_prec[f"int{bits}"] = rows
            per_enc[kind] = per_prec
        out[model] = per_enc
    if early_exits == 0:
        raise SystemExit(
            "early-exit goldens never exit early: the pins are vacuous"
        )
    return out


def gen_streams_golden():
    """Forge stream-family pins: per family, the window labels plus the
    FNV-1a64 of the raw pixel bytes (knobs: STREAM_KNOBS, golden seed)."""
    out = {}
    for name, gen in (("ecg", ecg_stream), ("kws", kws_stream), ("vib", vib_stream)):
        px, labels = gen(GOLDEN_SEED, **STREAM_KNOBS)
        out[name] = {
            "labels": [int(l) for l in labels],
            "pixels_fnv": f"{fnv1a64(bytes(px)):016x}",
        }
    return out


DECAY_WINDOWS = 3
DECAY_STEPS = 4


def gen_decay_golden():
    """``ResetPolicy::Decay(k)`` pins: the golden MLP run as a 3-window
    stream (4 steps each, one pixel frame per window, window-local
    encoder phase) with `v -= v >> k` applied to every membrane at each
    window boundary."""
    dim = MLP_SIZES[0]
    pix = pixels(GOLDEN_SEED, DECAY_WINDOWS, dim)
    shapes = list(zip(MLP_SIZES[:-1], MLP_SIZES[1:]))
    out = {}
    for k_shift in (1, 4, 7):
        per_prec = {}
        for bits in (2, 4, 8):
            theta = GOLDEN_THETA[bits]
            layers = [
                (raw_layer_q(GOLDEN_SEED, i, bits, k, n), theta)
                for i, (k, n) in enumerate(shapes)
            ]
            vs = [np.zeros(n, dtype=np.int64) for n in MLP_SIZES[1:]]
            rows = []
            for w in range(DECAY_WINDOWS):
                counts = infer_mlp_window(
                    MLP_SIZES, layers, pix[w * dim : (w + 1) * dim], DECAY_STEPS, vs
                )
                rows.append([int(c) for c in counts])
                for v in vs:
                    # numpy int64 >> is arithmetic, matching rust i32 >>
                    v -= v >> k_shift
            per_prec[f"int{bits}"] = rows
        out[f"k{k_shift}"] = per_prec
    return out


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    golden_dir = os.path.join(here, "..", "rust", "tests", "golden")
    os.makedirs(golden_dir, exist_ok=True)

    engine = gen_engine_golden()
    quant = gen_quant_golden()
    decay = gen_decay_golden()
    early = gen_early_exit_golden()
    streams = gen_streams_golden()

    # sanity: goldens must exercise real spiking activity per
    # configuration, not silence. Exception: trunc/INT2 — the truncation
    # scheme's power-of-two scale always covers amax, so every
    # sub-amplitude weight truncates to 0 at a 1-quantum range (exactly
    # the INT2 collapse the paper's Fig. 4 shows); its all-zero counts
    # are the faithful pin (the q/scale/theta layer records still bite).
    total = 0
    for model, per in engine.items():
        for prec, rows in per.items():
            spikes = sum(sum(r) for r in rows)
            total += spikes
            if spikes == 0 and prec != "int2":
                raise SystemExit(f"engine golden {model}/{prec} is silent: tune thetas")
    qtotal = 0
    for scheme, per in quant.items():
        for prec, rec in per.items():
            spikes = sum(sum(r) for r in rec["counts"])
            qtotal += spikes
            if spikes == 0 and (scheme, prec) != ("trunc", "int2"):
                raise SystemExit(f"quant golden {scheme}/{prec} is silent: tune thetas")
    if total == 0:
        raise SystemExit("engine goldens are all-zero: tune thetas")
    dtotal = 0
    for shift, per in decay.items():
        for prec, rows in per.items():
            spikes = sum(sum(r) for r in rows)
            dtotal += spikes
            if spikes == 0 and prec != "int2":
                raise SystemExit(f"decay golden {shift}/{prec} is silent: tune thetas")
    if dtotal == 0:
        raise SystemExit("decay goldens are all-zero: tune thetas")
    print(
        f"engine golden total spikes: {total}; quant golden total: {qtotal}; "
        f"decay golden total: {dtotal}"
    )

    with open(os.path.join(golden_dir, "engine.json"), "w") as f:
        json.dump({"seed": GOLDEN_SEED, "timesteps": T, "models": engine}, f, indent=1)
        f.write("\n")
    with open(os.path.join(golden_dir, "quant.json"), "w") as f:
        json.dump({"seed": GOLDEN_SEED, "timesteps": T, "schemes": quant}, f, indent=1)
        f.write("\n")
    with open(os.path.join(golden_dir, "decay.json"), "w") as f:
        json.dump(
            {
                "seed": GOLDEN_SEED,
                "steps": DECAY_STEPS,
                "windows": DECAY_WINDOWS,
                "shifts": decay,
            },
            f,
            indent=1,
        )
        f.write("\n")
    with open(os.path.join(golden_dir, "early_exit.json"), "w") as f:
        json.dump(
            {
                "seed": GOLDEN_SEED,
                "timesteps": T,
                "groups": POP_GROUPS,
                "models": early,
            },
            f,
            indent=1,
        )
        f.write("\n")
    with open(os.path.join(golden_dir, "streams.json"), "w") as f:
        json.dump(
            {"seed": GOLDEN_SEED, **STREAM_KNOBS, "families": streams}, f, indent=1
        )
        f.write("\n")
    print("wrote", golden_dir)


if __name__ == "__main__":
    sys.exit(main())
