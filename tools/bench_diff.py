#!/usr/bin/env python3
"""Diff two BENCH_*.json trajectory files and gate on perf regressions.

Each file holds one JSON object per line, as collected by `make bench-json`
from the `BENCH_JSON {...}` lines the benches print (see
rust/src/util/bench.rs::emit_json). Entries are keyed by
(suite, name, backend); rows without a `backend` field (pre-backend-sweep
trajectories) default to "scalar", so kernel-backend sweep rows of the
same bench name are always compared like-for-like instead of mixing
backends into one series.

The gate: any entry present in both runs whose `msynops_per_s` dropped by
more than --threshold (default 15%) fails the diff (exit 1). Other numeric
fields (median_ns, req_per_s, ...) are reported informationally.

Usage:
    tools/bench_diff.py BASE.json NEW.json [--threshold 0.15]

Example:
    git stash && make bench-json && cp BENCH_hotpath.json /tmp/base.json
    git stash pop && make bench-json
    tools/bench_diff.py /tmp/base.json BENCH_hotpath.json
"""

import argparse
import json
import sys

GATED_FIELD = "msynops_per_s"
# lower is better for timings; higher is better for rates
HIGHER_IS_BETTER = {GATED_FIELD, "req_per_s", "sim_utilization", "accuracy"}
LOWER_IS_BETTER = {"median_ns", "p10_ns", "p90_ns", "p50_us", "p99_us", "latency_us"}


def load(path):
    entries = {}
    with open(path, encoding="utf-8") as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                sys.exit(f"{path}:{line_no}: not a JSON line: {e}")
            key = (
                obj.get("suite", "?"),
                obj.get("name", f"line{line_no}"),
                obj.get("backend", "scalar"),
            )
            entries[key] = obj
    return entries


def fmt_delta(base, new, higher_is_better):
    if base == 0:
        return "   n/a"
    rel = (new - base) / abs(base)
    arrow = "+" if rel >= 0 else ""
    good = rel >= 0 if higher_is_better else rel <= 0
    marker = "" if good else " (worse)"
    return f"{arrow}{rel * 100.0:6.1f}%{marker}"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("base", help="baseline BENCH_*.json (one JSON object per line)")
    ap.add_argument("new", help="candidate BENCH_*.json to compare against the base")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="max tolerated relative drop of %s (default 0.15)" % GATED_FIELD,
    )
    args = ap.parse_args()

    base = load(args.base)
    new = load(args.new)
    common = sorted(set(base) & set(new))
    if not common:
        sys.exit("no common (suite, name) entries between the two runs")

    regressions = []
    print(f"{'suite/name':<48} {'field':<16} {'base':>14} {'new':>14}  delta")
    print("-" * 108)
    for key in common:
        b, n = base[key], new[key]
        fields = sorted(
            f
            for f in set(b) & set(n)
            if f not in ("suite", "name", "backend", "iters")
            and isinstance(b[f], (int, float))
            and isinstance(n[f], (int, float))
        )
        for f in fields:
            hib = f in HIGHER_IS_BETTER or (
                f not in LOWER_IS_BETTER and not f.endswith("_ns")
            )
            print(
                f"{'/'.join(key):<48} {f:<16} {b[f]:>14.1f} {n[f]:>14.1f}  "
                f"{fmt_delta(b[f], n[f], hib)}"
            )
            if f == GATED_FIELD and b[f] > 0:
                drop = (b[f] - n[f]) / b[f]
                if drop > args.threshold:
                    regressions.append((key, b[f], n[f], drop))

    missing = sorted(set(base) - set(new))
    added = sorted(set(new) - set(base))
    for key in missing:
        print(f"note: {'/'.join(key)} present only in base")
    for key in added:
        print(f"note: {'/'.join(key)} present only in new")

    if regressions:
        print()
        for key, b, n, drop in regressions:
            print(
                f"REGRESSION {'/'.join(key)}: {GATED_FIELD} {b:.1f} -> {n:.1f} "
                f"(-{drop * 100.0:.1f}% > {args.threshold * 100.0:.0f}% threshold)"
            )
        sys.exit(1)
    print(f"\nOK: no {GATED_FIELD} regression beyond {args.threshold * 100.0:.0f}%")


if __name__ == "__main__":
    main()
