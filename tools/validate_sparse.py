#!/usr/bin/env python3
"""Python mirror validation of the sparse-synapse pipeline.

Usage:  python3 tools/validate_sparse.py

Mirrors, bit-for-bit, the sparse Rust code:
- ``SparseRowIndex::build``    (chunk scan, adjacent-span merge, word counts)
- ``lif_step_plane_sparse``    (span-restricted accumulate + block spills)
- ``forge::prune_layer``       (block-granular magnitude pruning,
                               (l1, row, start) ordering, budget loop)

and checks, against the independent dense reference in
tools/gen_goldens.py:
 1. sparse walk == dense walk (spikes, membranes) on random shapes,
    including ragged final words and both block-spill boundaries, plus
    exact words_touched accounting and narrow-accumulator bounds;
 2. golden MLP + convnet end-to-end: counts identical sparse-vs-dense on
    0.0/0.5/0.9/0.99-pruned weights;
 3. the acceptance bound: at 0.9 sparsity the walk touches >= 5x fewer
    words than dense on BOTH golden archs at every precision;
 4. prune_layer determinism, zero-budget coverage, block alignment.
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import gen_goldens as g  # noqa: E402

FIELDS = {2: 16, 4: 8, 8: 4}
I8_BLOCK = {2: 63, 4: 15, 8: 0}
I16_BLOCK = 255
GOLDEN_THETA = g.GOLDEN_THETA


def build_index(w, fields):
    """Mirror of SparseRowIndex::build. w: [k,n] int array."""
    spans_per_row, row_words = [], []
    k, n = w.shape
    for r in range(k):
        spans, words = [], 0
        for s in range(0, n, fields):
            e = min(s + fields, n)
            if np.any(w[r, s:e] != 0):
                words += 1
                if spans and spans[-1][1] == s:
                    spans[-1][1] = e
                else:
                    spans.append([s, e])
        spans_per_row.append(spans)
        row_words.append(words)
    return spans_per_row, row_words


def sparse_lif_step(spikes, w, spans, row_words, v, theta, bits, leak=2):
    """Mirror of lif_step_plane_sparse: span-restricted accumulate with
    the same narrow-block spill cadence, returning words touched and the
    peak |narrow accumulator| (to prove the width bound still holds)."""
    n = w.shape[1]
    block = I8_BLOCK[bits] or I16_BLOCK
    acc_blk = np.zeros(n, dtype=np.int64)
    acc32 = np.zeros(n, dtype=np.int64)
    in_block, touched, peak = 0, 0, 0
    for j in np.nonzero(spikes)[0]:
        for s, e in spans[j]:
            acc_blk[s:e] += w[j, s:e]
        peak = max(peak, int(np.max(np.abs(acc_blk))) if n else 0)
        touched += row_words[j]
        in_block += 1
        if in_block == block:
            acc32 += acc_blk
            acc_blk[:] = 0
            in_block = 0
    acc32 += acc_blk
    v2 = v - (v >> leak) + acc32
    fired = (v2 >= theta).astype(np.int64)
    return fired, v2 - fired * theta, touched, peak


def prune_layer(q, sparsity, fields):
    """Mirror of forge::prune_layer: rank fields-wide blocks by
    (L1, row, start), zero smallest-first until the budget is covered."""
    if sparsity <= 0.0:
        return q.copy()
    k, n = q.shape
    budget = int(np.floor(sparsity * k * n))
    blocks = []
    for r in range(k):
        for s in range(0, n, fields):
            e = min(s + fields, n)
            l1 = int(np.sum(np.abs(q[r, s:e])))
            blocks.append((l1, r, s, e))
    blocks.sort()
    out = q.copy()
    zeroed = 0
    for _, r, s, e in blocks:
        if zeroed >= budget:
            break
        out[r, s:e] = 0
        zeroed += e - s
    return out


# ---------------------------------------------------------------------
# 1. random differential: sparse walk vs dense reference
# ---------------------------------------------------------------------


def check_random_walks():
    cases = 0
    for seed in range(250):
        rng = g.Rng(seed * 6151 + 17)
        bits = (2, 4, 8)[seed % 3]
        fields = FIELDS[bits]
        lo, hi = g.qrange(bits)
        # shapes crossing both spill boundaries (63/15 and 255 rows) and
        # ragged final words
        k = 1 + rng.below(600)
        n = 1 + rng.below(140)
        w = np.array(
            [[rng.range_i64(lo, hi) for _ in range(n)] for _ in range(k)],
            dtype=np.int64,
        )
        for r in range(k):
            for s in range(0, n, fields):
                e = min(s + fields, n)
                if rng.below(2) == 0:
                    w[r, s:e] = 0  # whole-block zero: must be skipped
                elif rng.below(4) == 0:
                    w[r, s] = 0  # partial zero: block must survive
        spans, row_words = build_index(w, fields)
        spikes = np.array([int(rng.f64() < 0.4) for _ in range(k)], dtype=np.int64)
        v0 = np.array([rng.range_i64(-40, 40) for _ in range(n)], dtype=np.int64)
        theta = GOLDEN_THETA[bits]

        fired_d, v_d = g.lif_rows(spikes, w, v0.copy(), theta)
        fired_s, v_s, touched, peak = sparse_lif_step(
            spikes, w, spans, row_words, v0.copy(), theta, bits
        )
        assert np.array_equal(fired_s, fired_d), f"seed {seed}: spikes diverge"
        assert np.array_equal(v_s, v_d), f"seed {seed}: membranes diverge"
        want_words = sum(row_words[j] for j in np.nonzero(spikes)[0])
        assert touched == want_words, f"seed {seed}: words {touched} != {want_words}"
        bound = 127 if I8_BLOCK[bits] else 32767
        assert peak <= bound, f"seed {seed}: narrow accumulator {peak} > {bound}"
        # sanity on the index itself: skipped chunks are exactly the
        # all-zero chunks
        for r in range(k):
            covered = np.zeros(n, dtype=bool)
            for s, e in spans[r]:
                covered[s:e] = True
            assert np.all(w[r, ~covered] == 0), f"seed {seed}: span missed a weight"
        cases += 1
    print(f"1. random walks: {cases} cases, sparse == dense everywhere")


# ---------------------------------------------------------------------
# 2+3. golden-arch end-to-end + the >= 5x acceptance bound
# ---------------------------------------------------------------------


def mlp_words(sizes, layers, pix, T, bits, spans_rw=None):
    """Run the golden MLP mirror, counting words touched per LIF layer:
    dense walk when spans_rw is None, sparse walk otherwise."""
    vs = [np.zeros(n, dtype=np.int64) for n in sizes[1:]]
    counts = np.zeros(sizes[-1], dtype=np.int64)
    px = np.array(pix, dtype=np.int64)
    words = 0
    fields = FIELDS[bits]
    for t in range(T):
        spk = g.spike_step(px, t)
        for i, (w, theta) in enumerate(layers):
            n_words = -(-w.shape[1] // fields)
            active = np.nonzero(spk)[0]
            if spans_rw is None:
                words += len(active) * n_words
                spk, vs[i] = g.lif_rows(spk, w, vs[i], theta)
            else:
                spans, row_words = spans_rw[i]
                spk, vs[i], touched, _ = sparse_lif_step(
                    spk, w, spans, row_words, vs[i], theta, bits
                )
                words += touched
        counts += spk
    return counts, words


def conv_words(side, channels, classes, layers, pix, T, bits, spans_rw=None):
    """Golden convnet mirror with word accounting on the three LIF banks
    (conv1 / conv2 / fc), dense or sparse walk."""
    c0, c1, c2 = channels
    s2 = side // 2
    t0, t1 = g.im2col_table(side, c0), g.im2col_table(s2, c1)
    fields = FIELDS[bits]
    v0 = np.zeros((side * side, c1), dtype=np.int64)
    v1 = np.zeros((s2 * s2, c2), dtype=np.int64)
    v2 = np.zeros(classes, dtype=np.int64)
    counts = np.zeros(classes, dtype=np.int64)
    px = np.array(pix, dtype=np.int64)
    (w0, th0), (w1, th1), (w2, th2) = layers
    words = 0

    def conv_bank(patches, w, th, v):
        nonlocal words
        n_words = -(-w.shape[1] // fields)
        fired = np.zeros((patches.shape[0], w.shape[1]), dtype=np.int64)
        vv_all = np.zeros_like(v)
        for posi in range(patches.shape[0]):
            spk = patches[posi]
            if spans_rw is None:
                words += int(np.count_nonzero(spk)) * n_words
                f, vv = g.lif_rows(spk, w, v[posi], th)
            else:
                spans, row_words = spans_rw[id(w)]
                f, vv, touched, _ = sparse_lif_step(
                    spk, w, spans, row_words, v[posi], th, bits
                )
                words += touched
            fired[posi] = f
            vv_all[posi] = vv
        return fired, vv_all

    for t in range(T):
        in_plane = g.spike_step(px, t)
        patches = g.gather(in_plane, t0).reshape(side * side, 9 * c0)
        fired, v0 = conv_bank(patches, w0, th0, v0)
        pooled1 = g.maxpool2(fired.reshape(-1), side, c1)
        patches2 = g.gather(pooled1, t1).reshape(s2 * s2, 9 * c1)
        fired, v1 = conv_bank(patches2, w1, th1, v1)
        pooled2 = g.maxpool2(fired.reshape(-1), s2, c2)
        if spans_rw is None:
            n_words_fc = -(-w2.shape[1] // fields)
            words += int(np.count_nonzero(pooled2)) * n_words_fc
            spk, v2 = g.lif_rows(pooled2, w2, v2, th2)
        else:
            spans, row_words = spans_rw[id(w2)]
            spk, v2, touched, _ = sparse_lif_step(
                pooled2, w2, spans, row_words, v2, th2, bits
            )
            words += touched
        counts += spk
    return counts, words


def check_golden_archs():
    T = g.T
    ratios = []
    # MLP
    sizes = g.MLP_SIZES
    shapes = list(zip(sizes[:-1], sizes[1:]))
    dim = sizes[0]
    pix = g.pixels(g.GOLDEN_SEED, 1, dim)
    for bits in (2, 4, 8):
        fields = FIELDS[bits]
        theta = GOLDEN_THETA[bits]
        raw = [
            g.raw_layer_q(g.GOLDEN_SEED, i, bits, k, n)
            for i, (k, n) in enumerate(shapes)
        ]
        for s in (0.0, 0.5, 0.9, 0.99):
            pruned = [prune_layer(w, s, fields) for w in raw]
            layers = [(w, theta) for w in pruned]
            spans_rw = [build_index(w, fields) for w in pruned]
            cd, wd = mlp_words(sizes, layers, pix, T, bits)
            cs, ws = mlp_words(sizes, layers, pix, T, bits, spans_rw)
            assert np.array_equal(cd, cs), f"mlp int{bits} s={s}: counts diverge"
            assert ws <= wd, f"mlp int{bits} s={s}: sparse words {ws} > dense {wd}"
            if s == 0.9:
                assert ws * 5 <= wd, f"mlp int{bits}: 0.9 ratio {wd}/{ws} < 5x"
                ratios.append(("mlp", bits, wd / max(ws, 1)))
    # convnet
    side, channels, classes = g.CONV["side"], g.CONV["channels"], g.CONV["classes"]
    dim = side * side * channels[0]
    pix = g.pixels(g.GOLDEN_SEED, 1, dim)
    shapes = g.conv_shapes(side, channels, classes)
    for bits in (2, 4, 8):
        fields = FIELDS[bits]
        theta = GOLDEN_THETA[bits]
        raw = [
            g.raw_layer_q(g.GOLDEN_SEED, i, bits, k, n)
            for i, (k, n) in enumerate(shapes)
        ]
        for s in (0.0, 0.5, 0.9, 0.99):
            pruned = [prune_layer(w, s, fields) for w in raw]
            layers = [(w, theta) for w in pruned]
            spans_rw = {id(w): build_index(w, fields) for w in pruned}
            cd, wd = conv_words(side, channels, classes, layers, pix, T, bits)
            cs, ws = conv_words(
                side, channels, classes, layers, pix, T, bits, spans_rw
            )
            assert np.array_equal(cd, cs), f"conv int{bits} s={s}: counts diverge"
            assert ws <= wd, f"conv int{bits} s={s}: sparse words {ws} > dense {wd}"
            if s == 0.9:
                assert ws * 5 <= wd, f"conv int{bits}: 0.9 ratio {wd}/{ws} < 5x"
                ratios.append(("convnet", bits, wd / max(ws, 1)))
    for name, bits, r in ratios:
        print(f"   {name} int{bits}: 0.9-sparsity words ratio {r:.1f}x (>= 5x ok)")
    print("2+3. golden archs: sparse == dense, 0.9 word ratios all >= 5x")


# ---------------------------------------------------------------------
# 4. prune rule properties
# ---------------------------------------------------------------------


def check_prune_properties():
    for seed in range(60):
        rng = g.Rng(seed * 389 + 11)
        bits = (2, 4, 8)[seed % 3]
        fields = FIELDS[bits]
        lo, hi = g.qrange(bits)
        k, n = 1 + rng.below(40), 1 + rng.below(70)
        q = np.array(
            [[rng.range_i64(lo, hi) for _ in range(n)] for _ in range(k)],
            dtype=np.int64,
        )
        for s in (0.5, 0.9):
            a = prune_layer(q, s, fields)
            b = prune_layer(q, s, fields)
            assert np.array_equal(a, b), f"seed {seed}: prune nondeterministic"
            budget = int(np.floor(s * k * n))
            assert int(np.sum(a == 0)) >= budget, f"seed {seed}: budget not covered"
            changed = (a != q)
            assert np.all(a[changed] == 0), f"seed {seed}: prune may only zero"
            for r in range(k):
                for st in range(0, n, fields):
                    e = min(st + fields, n)
                    if np.any(changed[r, st:e]):
                        assert np.all(a[r, st:e] == 0), (
                            f"seed {seed}: partial block zeroed"
                        )
        assert np.array_equal(prune_layer(q, 0.0, fields), q)
    print("4. prune rule: deterministic, budget-covering, block-aligned")


def main():
    check_random_walks()
    check_golden_archs()
    check_prune_properties()
    print("ALL SPARSE MIRROR CHECKS PASSED")


if __name__ == "__main__":
    main()
