#!/usr/bin/env python3
"""Python mirror validation of the fault-tolerance machinery.

Usage:  python3 tools/validate_faults.py

The container building these artifacts has no rust toolchain, so the
fault-path logic is mirrored here, bit-for-bit, and checked against
hand-computed expectations:

- ``FaultPlan::parse`` / ``parse_duration``  (the --faults grammar,
  including every rejection case pinned by the Rust unit tests);
- the exec-window claim protocol (``claim_exec`` / ``panic_in`` /
  ``stall_in`` / ``drop_reply_at``): exactly-once firing, the empty-plan
  u64::MAX sentinel, and the wrapping-add guard at drop call sites;
- ``alive_route``  (session rehoming: identical to s % workers while
  the pool is healthy, deterministic and surjective onto survivors
  when workers die, None when none remain);
- the loadgen retry backoff (xorshift64* mirror): deterministic per
  (seed, tag), jitter strictly inside [0.5x, 1.5x), exponential base
  doubling capped at 2^6.
"""

import sys

U64 = (1 << 64) - 1
SENTINEL = U64  # u64::MAX — the empty-plan claim_exec sentinel


# ---------------------------------------------------------------- grammar

def parse_duration_ms(s):
    """Mirror of faults::parse_duration (returns milliseconds)."""
    s = s.strip()
    if s.endswith("ms"):
        num, mult = s[:-2], 1
    elif s.endswith("s"):
        num, mult = s[:-1], 1000
    else:
        num, mult = s, 1
    num = num.strip()
    if not num.isdigit():
        raise ValueError(f"duration {s!r}: want e.g. 250ms or 2s")
    return int(num) * mult


def parse_plan(spec):
    """Mirror of FaultPlan::parse. Returns (exec_entries, resets) where
    exec_entries is a list of (at, kind, stall_ms_or_None)."""
    exec_entries, resets = [], []
    for part in (p.strip() for p in spec.split(",")):
        if not part:
            continue
        if "@" not in part:
            raise ValueError(f"fault entry {part!r}: want kind@index[:duration]")
        kind, rest = part.split("@", 1)
        if ":" in rest:
            idx_str, dur_str = rest.split(":", 1)
        else:
            idx_str, dur_str = rest, None
        idx_str = idx_str.strip()
        if not idx_str.isdigit():
            raise ValueError(f"fault entry {part!r}: index {idx_str!r} is not a u64")
        at = int(idx_str)
        kind = kind.strip()
        if kind == "panic" and dur_str is None:
            exec_entries.append((at, "panic", None))
        elif kind == "drop" and dur_str is None:
            exec_entries.append((at, "drop", None))
        elif kind == "reset" and dur_str is None:
            resets.append(at)
        elif kind == "stall" and dur_str is not None:
            exec_entries.append((at, "stall", parse_duration_ms(dur_str)))
        elif kind == "stall":
            raise ValueError(f"fault entry {part!r}: stall needs :duration")
        elif kind in ("panic", "drop", "reset"):
            raise ValueError(f"fault entry {part!r}: {kind} takes no duration")
        else:
            raise ValueError(f"fault entry {part!r}: unknown kind {kind!r}")
    return exec_entries, resets


def check_grammar():
    # the same round-trip the Rust unit test pins
    ex, rs = parse_plan("panic@6, stall@12:250ms ,drop@18,reset@2,stall@20:2s")
    assert ex == [(6, "panic", None), (12, "stall", 250), (18, "drop", None),
                  (20, "stall", 2000)], ex
    assert rs == [2], rs
    # bare numbers are milliseconds
    ex, _ = parse_plan("stall@0:40")
    assert ex == [(0, "stall", 40)]
    # empty / whitespace-only specs are the empty plan
    assert parse_plan("") == ([], [])
    assert parse_plan("  ,  ") == ([], [])
    # every rejection case from the Rust tests must also reject here
    for bad in ["panic", "panic@x", "stall@3", "panic@3:10ms",
                "jitter@1", "stall@1:fast", "reset@1:5ms", "drop@2:1s"]:
        try:
            parse_plan(bad)
        except ValueError:
            pass
        else:
            raise AssertionError(f"{bad!r} should have been rejected")
    print("grammar: parse + duration suffixes + rejections OK")


# ----------------------------------------------------- exec-window protocol

class Plan:
    """Mirror of the FaultPlan counter protocol."""

    def __init__(self, spec):
        self.exec, self.resets = parse_plan(spec)
        self.exec_counter = 0
        self.accept_counter = 0

    def claim_exec(self, n):
        if not self.exec:
            return SENTINEL
        base = self.exec_counter
        self.exec_counter += n
        return base

    def panic_in(self, base, n):
        return base != SENTINEL and any(
            k == "panic" and base <= at < base + n for at, k, _ in self.exec)

    def stall_in(self, base, n):
        if base == SENTINEL:
            return None
        total = sum(ms for at, k, ms in self.exec
                    if k == "stall" and base <= at < base + n)
        return total or None

    def drop_reply_at(self, idx):
        return idx != SENTINEL and any(
            k == "drop" and at == idx for at, k, _ in self.exec)

    def reset_accept(self):
        if not self.resets:
            return False
        idx = self.accept_counter
        self.accept_counter += 1
        return idx in self.resets


def check_exec_windows():
    p = Plan("panic@6,stall@12:5ms,drop@13")
    b0 = p.claim_exec(4)
    assert b0 == 0 and not p.panic_in(b0, 4) and p.stall_in(b0, 4) is None
    b1 = p.claim_exec(4)
    assert p.panic_in(b1, 4)  # index 6 in [4,8)
    b2 = p.claim_exec(6)
    assert p.stall_in(b2, 6) == 5
    assert not p.drop_reply_at(b2 + 4) and p.drop_reply_at(b2 + 5)
    b3 = p.claim_exec(100)
    assert not p.panic_in(b3, 100) and p.stall_in(b3, 100) is None

    # empty plan: sentinel base, and the wrapping-add at drop call sites
    # (base.wrapping_add(i)) can never match a planned index
    e = Plan("")
    base = e.claim_exec(8)
    assert base == SENTINEL
    for i in range(8):
        wrapped = (base + i) & U64  # u64 wrapping_add mirror
        assert not e.drop_reply_at(wrapped)
    assert not e.panic_in(base, 8) and e.stall_in(base, 8) is None

    # resets count accepted connections, firing exactly once
    r = Plan("reset@1")
    assert [r.reset_accept() for _ in range(3)] == [False, True, False]
    print("exec windows: claim/fire-once/sentinel/wrapping OK")


# ------------------------------------------------------------- alive_route

def alive_route(session, alive):
    """Mirror of server::alive_route."""
    live = sum(alive)
    if live == 0:
        return None
    k = session % live
    return [i for i, a in enumerate(alive) if a][k]


def check_alive_route():
    # healthy pool == the historical s % workers contract
    for w in (1, 2, 3, 8):
        alive = [True] * w
        for s in range(100):
            assert alive_route(s, alive) == s % w
    # one dead worker: deterministic, never routes to the corpse, and
    # the surviving shards all still receive sessions
    alive = [True, False, True, True]
    got = {alive_route(s, alive) for s in range(100)}
    assert got == {0, 2, 3}, got
    for s in range(100):
        assert alive_route(s, alive) == alive_route(s, alive)
    # session affinity is stable *within* a pool configuration
    assert alive_route(5, alive) == [0, 2, 3][5 % 3]
    # all dead: typed failure upstream, never a panic
    assert alive_route(7, [False, False]) is None
    print("alive_route: healthy==s%w, deterministic rehoming, all-dead OK")


# ------------------------------------------------------------ retry backoff

def xorshift64star(seed):
    """Mirror of util::Rng (xorshift64*), yielding u64s."""
    state = max(seed & U64, 1)
    while True:
        state ^= (state << 13) & U64
        state ^= state >> 7
        state ^= (state << 17) & U64
        yield (state * 0x2545F4914F6CDD1D) & U64


def rng_f64(seed):
    """First Rng::f64 draw for a seed."""
    return (next(xorshift64star(seed)) >> 11) / float(1 << 53)


def retry_delay_ms(backoff_ms, attempt, tag, seed):
    """Mirror of loadgen::RetryPolicy::delay (milliseconds, float)."""
    exp = min(max(attempt - 1, 0), 6)
    base = backoff_ms * float(1 << exp)
    jitter = 0.5 + rng_f64(seed ^ ((tag * 0x9E3779B97F4A7C15) & U64))
    return base * jitter


def check_backoff():
    # deterministic per (seed, tag)
    for tag in (0, 1, 17, 2**40):
        a = retry_delay_ms(50, 3, tag, seed=7)
        b = retry_delay_ms(50, 3, tag, seed=7)
        assert a == b
    # jitter strictly inside [0.5x, 1.5x) of the exponential base
    for attempt in range(1, 10):
        exp = min(attempt - 1, 6)
        base = 50 * (1 << exp)
        for tag in range(200):
            d = retry_delay_ms(50, attempt, tag, seed=42)
            assert 0.5 * base <= d < 1.5 * base, (attempt, tag, d)
    # base doubles per attempt and caps at 2^6
    assert retry_delay_ms(50, 8, 3, 9) == retry_delay_ms(50, 7, 3, 9)
    lo_hi = [(0.5 * 50 * (1 << min(a - 1, 6)), 1.5 * 50 * (1 << min(a - 1, 6)))
             for a in (1, 2, 3)]
    assert lo_hi[1][0] == 2 * lo_hi[0][0] and lo_hi[2][0] == 2 * lo_hi[1][0]
    # different tags actually spread (desynchronized retry storms)
    draws = {round(retry_delay_ms(50, 1, t, seed=1), 6) for t in range(64)}
    assert len(draws) > 32, f"jitter collapsed: {len(draws)} distinct of 64"
    print("backoff: deterministic, [0.5x,1.5x) jitter, 2^6 cap, spread OK")


def main():
    check_grammar()
    check_exec_windows()
    check_alive_route()
    check_backoff()
    print("validate_faults: ALL OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
